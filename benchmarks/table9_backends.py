"""Table 9 (beyond-paper): the lookup-backend plan matrix.

Sweeps the cells of `repro.core.lookup`'s placement × storage × kernel
registry over one shared table draw and a drifting-hot-set access stream
(table6/7's decode-like pattern), reporting per-lookup latency and the max
abs output delta vs the dense fp32 reference — which must sit inside the
documented `repro.quant.max_abs_error_bound` for quantized storages and
float rounding for fp32.

    PYTHONPATH=src python -m benchmarks.run table9 --smoke   # harness rows
    PYTHONPATH=src python -m benchmarks.table9_backends

Sharded placements run under an in-process 1-device mesh with a `model`
axis — the layout/communication structure is exercised (shard_map + psum /
per-range routing), while multi-device equivalence lives in the slow
subprocess tests.  The smoke sweep times the reference-kernel cells
(tracked in `benchmarks/baseline.json`, gated at 1.3x by
`tools/check_bench.py` like every other hot path — sharded-tiered
included); the full sweep adds the Pallas cells, which run in interpret
mode on CPU and are timed with a reduced stream.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.core import lookup, lram
from repro.distributed import context as _ctx
from repro.memstore import TieredSpec

M = 64
TOP_K = 32


def _params(smoke: bool):
    if smoke:
        # steps chosen for stable medians: the store-backed cells are
        # host-routing heavy and their per-call times jitter more than
        # the pure device gathers the gate calibrates on
        return dict(num_rows=2**14, shard_rows=512, batch=128,
                    steps=10, warmup=3)
    return dict(num_rows=2**16, shard_rows=2048, batch=256,
                steps=10, warmup=3)


def _cells(smoke: bool):
    ref_cells = [
        ("dense", "fp32", "reference"),
        ("dense", "int8", "reference"),
        ("tiered", "fp32", "reference"),
        ("tiered", "int8", "reference"),
        ("sharded", "fp32", "reference"),
        ("sharded-tiered", "fp32", "reference"),
        ("sharded-tiered", "int8", "reference"),
    ]
    if smoke:
        return ref_cells
    full = [(p, s, k)
            for p in lookup.PLACEMENTS
            for s in lookup.STORAGES
            for k in lookup.KERNELS]
    return ref_cells + [c for c in full if c not in ref_cells]


def _make_cfg(placement, storage, kernel, p):
    log2 = int(np.log2(p["num_rows"]))
    kw = dict(
        log2_locations=log2, m=M, heads=4, query_norm="rms",
        table_quant="none" if storage == "fp32" else storage,
        lookup_kernel=kernel,
    )
    num_shards = p["num_rows"] // p["shard_rows"]
    slots = max(2, num_shards // 4)  # 25% resident: fills on the clock
    if placement == "dense":
        return lram.LRAMConfig(interp_impl="reference", **kw)
    if placement == "tiered":
        return lram.LRAMConfig(
            interp_impl="tiered",
            tiered=TieredSpec(shard_rows=p["shard_rows"], cache_slots=slots),
            **kw,
        )
    if placement == "sharded":
        return lram.LRAMConfig(interp_impl="sharded", **kw)
    return lram.LRAMConfig(
        interp_impl="sharded-tiered", model_shards=2,
        tiered=TieredSpec(shard_rows=p["shard_rows"],
                          cache_slots=max(1, slots // 2)),
        **kw,
    )


def _stream(rng, steps, num_rows, batch):
    """table6's decode-like pattern: a drifting hot window so tiered fills
    stay on the clock while hits dominate."""
    hot_span = num_rows // 8
    center = 0
    for _ in range(steps):
        center = (center + rng.integers(0, num_rows // 16)) % num_rows
        yield ((center + rng.integers(0, hot_span, (batch, TOP_K)))
               % num_rows).astype(np.int32)


def _time_cell(interp_fn, rng, p, *, steps=None):
    times = []
    steps = p["steps"] if steps is None else steps
    for t, idx in enumerate(_stream(rng, steps, p["num_rows"], p["batch"])):
        w = rng.normal(size=idx.shape).astype(np.float32) / TOP_K
        t0 = time.perf_counter()
        out = interp_fn(idx, w)
        jax.block_until_ready(out)
        if t >= min(p["warmup"], steps - 1):
            times.append(time.perf_counter() - t0)
    return 1e6 * float(np.median(times))


def _accuracy(plan, table, dense, rng, p, storage):
    idx = rng.integers(0, p["num_rows"], size=(64, TOP_K)).astype(np.int32)
    w = rng.normal(size=idx.shape).astype(np.float32) / TOP_K
    want = np.einsum("...k,...km->...m", w, dense[idx])
    got = np.asarray(plan.interp(table, jnp.asarray(idx), jnp.asarray(w)))
    err = float(np.abs(got - want).max())
    if storage == "fp32":
        bound = 1e-4
    else:
        _, scale = quant.quantize_rows_np(dense, storage)
        bound = quant.max_abs_error_bound(scale, w, storage)
    return err, bound


def measure(smoke: bool = False):
    p = _params(smoke)
    rng = np.random.default_rng(0)
    dense = rng.normal(size=(p["num_rows"], M)).astype(np.float32) * 0.02
    dense_dev = jnp.asarray(dense)
    mesh = jax.make_mesh((1,), ("model",))
    rows = []
    for placement, storage, kernel in _cells(smoke):
        cfg = _make_cfg(placement, storage, kernel, p)
        if placement == "sharded":
            _ctx.set_mesh(mesh)
        try:
            plan = lookup.resolve(cfg)
            table = plan.build_table(dense_dev)
            eager = plan.supports_prefetch  # store-backed: host cache walk
            if eager:
                if hasattr(table, "warm"):
                    table.warm()

                def fn(idx, w, _t=table, _pl=plan):
                    return _pl.interp(_t, idx, w)
            else:
                jitted = jax.jit(
                    lambda i, w, _t=table, _pl=plan: _pl.interp(_t, i, w)
                )

                def fn(idx, w, _j=jitted):
                    return _j(jnp.asarray(idx), jnp.asarray(w))

            # pallas cells run in interpret mode on CPU: tiny stream.
            # jitted device cells are sub-ms dispatch-dominated calls —
            # give them 3x the samples so the median rides out scheduler
            # jitter (they are gated at 1.3x in CI)
            if kernel == "pallas" and jax.default_backend() != "tpu":
                steps = 3
            elif not eager:
                steps = 3 * p["steps"]
            else:
                steps = None
            us = _time_cell(fn, np.random.default_rng(1), p, steps=steps)
            err, bound = _accuracy(plan, table, dense,
                                   np.random.default_rng(2), p, storage)
            assert err <= bound + 1e-6, (
                f"{plan.cell}: err {err:.3e} exceeds bound {bound:.3e}"
            )
            derived = f"err={err:.2e} bound={bound:.2e}"
            if hasattr(table, "hit_rate"):
                derived += f" hit={table.hit_rate():.3f}"
        finally:
            if placement == "sharded":
                _ctx.set_mesh(None)
        name = f"backend_{placement}_{storage}_{kernel}".replace("-", "_")
        rows.append((name, us, derived))
    return rows


def run(smoke: bool = False):
    return measure(smoke=smoke)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    for name, us, derived in run(smoke=args.smoke):
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
