"""Benchmark harness — one module per paper table.

    PYTHONPATH=src python -m benchmarks.run [table1 table3 ...]

Prints ``name,us_per_call,derived`` CSV.  Each module exposes
``run() -> list[(name, us_per_call, derived)]``.
"""

from __future__ import annotations

import sys
import time
import traceback

MODULES = ("table1_lattice", "table2_lm", "table3_opcounts",
           "table4_timing", "table5_utilisation", "table6_tiering",
           "table7_quant")


def main() -> None:
    selected = set(a.split("_")[0] for a in sys.argv[1:])
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        if selected and mod_name.split("_")[0] not in selected:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.3f},{derived}")
        except Exception as e:
            failures += 1
            print(f"{mod_name}.ERROR,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        print(f"# {mod_name} took {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
