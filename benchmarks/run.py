"""Benchmark harness — one module per paper table.

    PYTHONPATH=src python -m benchmarks.run [table1 table3 ...] \
        [--smoke] [--json] [--out BENCH_ci.json]

Default output is ``name,us_per_call,derived`` CSV.  ``--json`` emits one
machine-readable *summary document* instead — the same schema
`repro.launch.serve --json` uses (top-level ``rows`` holding
``[name, us_per_call, derived]`` triples; `validate_summary` below is the
shared contract both emitters and `tools/check_bench.py` check against).
``--smoke`` asks each module for its reduced sweep (passed through to
``run(smoke=True)`` where the module supports it) — this is what the CI
``bench`` job runs before gating on `benchmarks/baseline.json`.

``--metrics-dir DIR`` arms the observability layer (`repro.obs`) for the
whole sweep: instrumented layers (serving engine, tiered store, lifecycle
controller, table5's utilisation gauges) stream to ``DIR/metrics.jsonl``
and a Prometheus textfile snapshot, and the summary document carries the
final registry snapshot under its ``metrics`` key (``repro.obs.v1`` —
`tools/check_bench.py` gates on it).

Each module exposes ``run() -> list[(name, us_per_call, derived)]``.
"""

from __future__ import annotations

import argparse
import inspect
import json
import math
import sys
import time
import traceback

MODULES = ("table1_lattice", "table2_lm", "table3_opcounts",
           "table4_timing", "table5_utilisation", "table6_tiering",
           "table7_quant", "table8_serving", "table9_backends",
           "table10_lifecycle")


def validate_summary(doc) -> None:
    """Assert `doc` is a benchmark summary document.

    The shared schema (emitted by both ``benchmarks.run --json`` and
    ``repro.launch.serve --json``): a JSON object whose ``rows`` key holds
    a list of ``[name, us_per_call, derived]`` triples — name a non-empty
    string, us_per_call a finite non-negative number, derived a string.
    Extra keys are allowed (each emitter adds its own detail fields).
    Raises ValueError on the first violation.
    """
    if not isinstance(doc, dict):
        raise ValueError(f"summary must be an object, got {type(doc)}")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        raise ValueError("summary must carry a non-empty 'rows' list")
    for i, row in enumerate(rows):
        if not (isinstance(row, (list, tuple)) and len(row) == 3):
            raise ValueError(f"rows[{i}]: expected [name, us, derived]")
        name, us, derived = row
        if not isinstance(name, str) or not name:
            raise ValueError(f"rows[{i}]: name must be a non-empty string")
        if (isinstance(us, bool) or not isinstance(us, (int, float))
                or not math.isfinite(us) or us < 0):
            raise ValueError(
                f"rows[{i}] ({name}): us_per_call must be a finite "
                f"non-negative number, got {us!r}"
            )
        if not isinstance(derived, str):
            raise ValueError(f"rows[{i}] ({name}): derived must be a string")
    if "metrics" in doc:
        from repro.obs import export as obs_export
        try:
            obs_export.validate_metrics_doc(doc["metrics"])
        except ValueError as e:
            raise ValueError(f"summary 'metrics' doc invalid: {e}") from e


def collect(tables: list[str], *, smoke: bool = False):
    """Run the selected modules; returns (rows, failures)."""
    selected = set(a.split("_")[0] for a in tables)
    rows: list[tuple[str, float, str]] = []
    failures = 0
    for mod_name in MODULES:
        if selected and mod_name.split("_")[0] not in selected:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            kwargs = {}
            if smoke and "smoke" in inspect.signature(mod.run).parameters:
                kwargs["smoke"] = True
            rows.extend((name, us, derived)
                        for name, us, derived in mod.run(**kwargs))
        except Exception as e:
            failures += 1
            rows.append((f"{mod_name}.ERROR", 0.0, f"{type(e).__name__}: {e}"))
            traceback.print_exc(file=sys.stderr)
        print(f"# {mod_name} took {time.time()-t0:.1f}s", file=sys.stderr)
    return rows, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("tables", nargs="*",
                    help="table selections (e.g. table1 table6); "
                         "default: all")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweeps (modules that support smoke=True)")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary document instead of CSV")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="also write the summary document to FILE "
                         "(e.g. BENCH_ci.json; implies the JSON schema)")
    ap.add_argument("--metrics-dir", default="", metavar="DIR",
                    help="arm repro.obs for the sweep: JSONL event log + "
                         "Prometheus textfile in DIR, registry snapshot "
                         "in the summary's 'metrics' key")
    args = ap.parse_args(argv)

    from repro import obs
    if args.metrics_dir:
        obs.configure(metrics_dir=args.metrics_dir)
    rows, failures = collect(args.tables, smoke=args.smoke)
    if args.metrics_dir:
        obs.flush()
    doc = {
        "rows": [[name, us, derived] for name, us, derived in rows],
        "tables": args.tables or list(MODULES),
        "smoke": args.smoke,
        "failures": failures,
        "metrics": obs.metrics_doc(),
    }
    validate_summary(doc)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {args.out}", file=sys.stderr)
    if args.json:
        print(json.dumps(doc))
    else:
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}")
    if failures:
        print(f"{failures} benchmark modules failed", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
