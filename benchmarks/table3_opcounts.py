"""Paper Table 3: parameter counts and op counts per layer type.

Analytic formulas (r = d_ff/w = 4):

    dense 2-layer   params 2rw^2            ops 2rw^2
    PKM             params mN + 2w sqrt(N) + w^2    ops 2w sqrt(N) + w^2
    LRAM            params mN + (5/4)rw^2   ops (5/4)rw^2

plus a *measured* check that compiled LRAM-lookup FLOPs are O(1) in N
(the central systems claim), from compiled cost_analysis.
"""

import jax

from repro.core import lram


def _measure_lookup_flops(log2_n: int) -> float:
    cfg = lram.LRAMConfig(log2_locations=log2_n, m=64, heads=4,
                          query_norm="rms")
    params, state = lram.lram_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, cfg.in_dim))

    def f(values, x):
        p = dict(params)
        p["values"] = values
        y, _ = lram.lram_apply(p, state, x, cfg)
        return y

    c = jax.jit(f).lower(params["values"], x).compile()
    return c.cost_analysis().get("flops", 0.0)


def run() -> list[tuple[str, float, str]]:
    w, r, m = 512, 4, 64
    rows = []
    for name, n_mem in (("2^18", 2**18), ("2^20", 2**20), ("2^22", 2**22)):
        dense_p = 2 * r * w * w
        pkm_p = m * n_mem + 2 * w * int(n_mem**0.5) + w * w
        lram_p = m * n_mem + (5 * r * w * w) // 4
        rows.append((
            f"table3.params_w512_N{name}", 0.0,
            f"dense {dense_p/1e6:.1f}M | pkm {pkm_p/1e6:.1f}M | "
            f"lram {lram_p/1e6:.1f}M",
        ))
    dense_ops = 2 * r * w * w
    pkm_ops = 2 * w * 256 + w * w
    lram_ops = (5 * r * w * w) // 4
    rows.append((
        "table3.ops_per_token_w512", 0.0,
        f"dense {dense_ops/1e6:.2f}M | pkm {pkm_ops/1e6:.2f}M | "
        f"lram {lram_ops/1e6:.2f}M (paper: lram = (5/4)rw^2, O(1) in N)",
    ))
    f16 = _measure_lookup_flops(16)
    f20 = _measure_lookup_flops(20)
    rows.append((
        "table3.compiled_lookup_flops_O1_in_N", 0.0,
        f"N=2^16: {f16:.3g} | N=2^20: {f20:.3g} | "
        f"ratio {f20 / max(f16, 1):.4f} (O(1) claim: ratio ~ 1)",
    ))
    return rows
