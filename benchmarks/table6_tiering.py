"""Table 6 (beyond-paper): tiered memory — hit rate & latency vs cache size.

Sweeps the device-cache fraction of a host-offloaded value table
(repro.memstore) under a decode-like access stream (a drifting hot set with
a cold random tail — the locality regime the serve path produces) and
reports per-lookup latency with the measured cache hit rate, against the
dense device-resident gather as the reference row.

    PYTHONPATH=src python -m benchmarks.run table6
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lram
from repro.memstore import TieredSpec, TieredValueStore

NUM_ROWS = 2**16
M = 64
SHARD_ROWS = 2048          # 32 shards
BATCH, TOP_K = 256, 32
STEPS, WARMUP = 12, 3
FRACTIONS = (0.125, 0.25, 0.5, 1.0)


def _stream(rng, steps, *, hot=True):
    """Decode-like access pattern: a hot window drifting across the torus
    (consecutive decode steps revisit nearby lattice buckets).  hot=False
    is the adversarial uniform stream — no locality for the cache to find."""
    hot_span = NUM_ROWS // 8
    center = 0
    for _ in range(steps):
        if not hot:
            yield rng.integers(0, NUM_ROWS, (BATCH, TOP_K)).astype(np.int32)
            continue
        center = (center + rng.integers(0, NUM_ROWS // 16)) % NUM_ROWS
        yield ((center + rng.integers(0, hot_span, (BATCH, TOP_K)))
               % NUM_ROWS).astype(np.int32)


def _time_stream(gather, rng, *, hot=True, steps=STEPS):
    times = []
    for t, idx in enumerate(_stream(rng, steps, hot=hot)):
        w = rng.normal(size=idx.shape).astype(np.float32)
        t0 = time.perf_counter()
        out = gather(idx, w)
        jax.block_until_ready(out)
        if t >= WARMUP:
            times.append(time.perf_counter() - t0)
    return 1e6 * float(np.mean(times))


def run(smoke: bool = False):
    rows = []
    steps = 9 if smoke else STEPS
    fractions = (0.25, 1.0) if smoke else FRACTIONS
    rng = np.random.default_rng(0)
    dense = rng.normal(size=(NUM_ROWS, M)).astype(np.float32) * 0.02

    dense_dev = jnp.asarray(dense)
    ref = jax.jit(lram.gather_interp)
    us = _time_stream(lambda i, w: ref(dense_dev, jnp.asarray(i),
                                       jnp.asarray(w)),
                      np.random.default_rng(1), steps=steps)
    rows.append(("tiering_dense_reference", us, "hit=1.0 resident=1.0"))

    num_shards = NUM_ROWS // SHARD_ROWS
    for frac in fractions:
        slots = max(1, int(num_shards * frac))
        store = TieredValueStore.from_dense(
            dense, TieredSpec(shard_rows=SHARD_ROWS, cache_slots=slots)
        )
        store.warm()
        store.reset_stats()
        us = _time_stream(store.gather, np.random.default_rng(1),
                          steps=steps)
        rows.append((
            f"tiering_cache_{frac:g}",
            us,
            f"hit={store.hit_rate():.3f} "
            f"evictions={store.stats['evictions']} "
            f"uncached={store.stats['uncached']}",
        ))

    # adversarial reference: uniform accesses, nothing for LRU to exploit
    store = TieredValueStore.from_dense(
        dense, TieredSpec(shard_rows=SHARD_ROWS, cache_slots=num_shards // 4)
    )
    store.warm()
    store.reset_stats()
    us = _time_stream(store.gather, np.random.default_rng(1), hot=False,
                      steps=steps)
    rows.append((
        "tiering_cache_0.25_uniform", us,
        f"hit={store.hit_rate():.3f} uncached={store.stats['uncached']}",
    ))
    return rows
