"""Paper Table 2 (shape-reproduction at CPU scale).

The paper's effect — memory-augmented models beat the baseline, improving
with capacity — emerges in the underfit regime on 227M paragraphs, which a
single CPU core cannot reach (DESIGN.md §7).  Two CPU-scale measurements
capture the *mechanism*:

  1. **Capacity probe** (layer level): train a dense FFN block vs the
     paper's LRAM mem-FFN block (identical interface, w=64) to memorise K
     random (query -> value) pairs.  The dense block saturates as K exceeds
     its parameter capacity; LRAM keeps the write-then-read error low — the
     capacity-at-O(1)-cost property that drives the paper's Table 2.
  2. **Fact-recall LM** (model level): MLM training on the synthetic corpus
     with 64 planted key->value facts; reports eval xent + recall on masked
     values for baseline / PKM / LRAM at equal steps.
"""

import time

import jax
import jax.numpy as jnp

from repro import configs, data, optim
from repro.core import lram
from repro.launch.train import build_train_step, evaluate
from repro.models import transformer

STEPS = 400
BATCH = 16
SEQ = 64
W = 64


# ---------------------------------------------------------------------------
# 1. layer-level capacity probe
# ---------------------------------------------------------------------------

def _train_block(apply_fn, params, qs, vs, steps=300, lr=2e-2):
    opt_cfg = optim.OptimConfig(lr=lr, memory_lr_mult=10.0, grad_clip=0.0)

    def loss(p):
        return jnp.mean((apply_fn(p, qs) - vs) ** 2)

    vg = jax.jit(jax.value_and_grad(loss))
    st = optim.adam_init(params)
    for _ in range(steps):
        l, g = vg(params)
        params, st, _ = optim.adam_update(g, st, params, opt_cfg)
    return float(vg(params)[0])


def _capacity_probe(n_pairs: int, key):
    k1, k2, k3 = jax.random.split(key, 3)
    qs = jax.random.normal(k1, (n_pairs, W))
    vs = jax.random.normal(k2, (n_pairs, W))

    # dense 2-layer FFN block (w -> 4w -> w)
    from repro import nn
    dp = {
        "wi": nn.dense_init(k3, W, 4 * W),
        "wo": nn.dense_init(k1, 4 * W, W),
    }
    dense_mse = _train_block(
        lambda p, x: nn.dense(p["wo"], jax.nn.gelu(nn.dense(p["wi"], x))),
        dp, qs, vs,
    )

    # the paper's mem-FFN block, same interface
    mcfg = lram.memffn_config(W, 16, query_norm="rms")
    mp, ms = lram.memffn_init(k3, W, mcfg)
    lram_mse = _train_block(
        lambda p, x: lram.memffn_apply(p, ms, x, mcfg)[0], mp, qs, vs,
    )
    return dense_mse, lram_mse


# ---------------------------------------------------------------------------
# 2. model-level fact recall
# ---------------------------------------------------------------------------

def _train_one(arch_variant: str, seed: int = 0):
    cfg = configs.get_smoke_config(arch_variant)
    dcfg = data.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=SEQ, global_batch=BATCH,
        kind="facts", objective="mlm", num_facts=64, fact_density=1.0,
        mask_prob=0.25, seed=1234,
    )
    opt_cfg = optim.OptimConfig(lr=1e-3, memory_lr_mult=10.0)
    params, mstate = transformer.init(jax.random.PRNGKey(seed), cfg)
    step_fn = build_train_step(cfg, opt_cfg)
    opt_state = optim.adam_init(params)
    resid = jnp.zeros(())
    t0 = time.time()
    table = data.make_fact_table(dcfg)
    for step in range(STEPS):
        batch = jax.tree.map(
            jnp.asarray, data.get_batch(dcfg, step=step, table=table)
        )
        params, opt_state, mstate, resid, metrics = step_fn(
            params, opt_state, mstate, resid, batch
        )
    dt = time.time() - t0
    eval_loss, recall = evaluate(params, mstate, cfg, dcfg)
    n_params = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
    return eval_loss, recall, n_params, 1e6 * dt / (STEPS * BATCH * SEQ)


def run() -> list[tuple[str, float, str]]:
    rows = []
    key = jax.random.PRNGKey(0)
    caps = {}
    for n_pairs in (256, 1024, 4096):
        dense_mse, lram_mse = _capacity_probe(n_pairs, key)
        caps[n_pairs] = (dense_mse, lram_mse)
        rows.append((
            f"table2.capacity_{n_pairs}_pairs", 0.0,
            f"dense-FFN mse {dense_mse:.4f} | LRAM mse {lram_mse:.4f} | "
            f"advantage {dense_mse/max(lram_mse,1e-9):.1f}x",
        ))
    rows.append((
        "table2.capacity_claim", 0.0,
        "LRAM write-then-read capacity >> dense at equal interface "
        f"(4096 pairs: {caps[4096][0]:.3f} vs {caps[4096][1]:.3f}; "
        "the mechanism behind the paper's Table 2 scaling)",
    ))

    results = {}
    for variant in ("lram-bert-baseline", "lram-bert-pkm",
                    "lram-bert-small"):
        loss, recall, n, us = _train_one(variant)
        results[variant] = (loss, recall)
        rows.append((
            f"table2.{variant}", us,
            f"eval_xent {loss:.4f} | fact_recall {recall:.3f} | "
            f"params {n/1e6:.2f}M | {STEPS} steps",
        ))
    rows.append((
        "table2.note", 0.0,
        "full Table-2 ordering needs the underfit web-corpus regime "
        "(227M paragraphs); at CPU scale the capacity probe above carries "
        "the claim",
    ))
    return rows
