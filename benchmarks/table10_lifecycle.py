"""Table 10 (beyond-paper): memory lifecycle — growth, migration, recovery.

Three families of rows (all `repro.memctl`):

* ``lifecycle_grow_<placement>_<storage>`` — wall-clock pause of
  `memctl.grow` doubling the table (N → 2N), with the growth-equivalence
  check inline: lookups at pre-growth *points* (the same geometric query
  positions, re-encoded on the grown torus) must match pre-growth outputs
  within float rounding for every storage kind — the appended rows are
  bit-copies of their coarse-lattice parents.
* ``lifecycle_migrate_<src>_<dst>`` — wall-clock pause of
  `memctl.migrate` moving the table between placement cells; the final
  leg asserts the dense → tiered → sharded-tiered → dense round trip is
  payload-exact.
* ``lifecycle_util_recovery`` — dead-bin fraction before growth, right
  after growth (the appended half starts dead), and after a stream of
  lattice-query steps (`memctl.telemetry`): how fast the grown capacity
  comes alive under uniform query traffic.

    PYTHONPATH=src python -m benchmarks.run table10 --smoke  # harness rows
    PYTHONPATH=src python -m benchmarks.table10_lifecycle

Pause times are one-shot measurements (a growth happens once, not in a
steady-state loop), so `benchmarks/baseline.json` tracks these rows for
presence only (us = 0) — the gate checks they exist and error-check, not
their jitter.
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro import memctl
from repro.core import lookup, lram
from repro.memstore import TieredSpec

TOP_K = 32


def _params(smoke: bool):
    if smoke:
        return dict(log2=16, m=16, queries=64, recovery_steps=12)
    return dict(log2=17, m=64, queries=256, recovery_steps=32)


def _make_cfg(placement, storage, p, log2=None):
    kw = dict(
        log2_locations=log2 or p["log2"], m=p["m"], heads=2,
        query_norm="rms", top_k=TOP_K,
        table_quant="none" if storage == "fp32" else storage,
    )
    if placement == "dense":
        return lram.LRAMConfig(interp_impl="reference", **kw)
    if placement == "tiered":
        return lram.LRAMConfig(
            interp_impl="tiered",
            tiered=TieredSpec(shard_rows=4096, cache_slots=4), **kw,
        )
    return lram.LRAMConfig(
        interp_impl="sharded-tiered", model_shards=2,
        tiered=TieredSpec(shard_rows=2048, cache_slots=2), **kw,
    )


def _query_points(rng, n, spec):
    """Uniform positions over the torus box (any reals work: encoding
    wraps; uniform traffic is the recovery benchmark's best case)."""
    return jnp.asarray(
        rng.uniform(0, np.asarray(spec.K), size=(n, 8)).astype(np.float32)
    )


def _interp_at(cfg, table, q):
    plan = lookup.resolve(cfg)
    idx, w = lram.indices_and_weights(q, cfg.torus_spec, cfg.top_k)
    return np.asarray(plan.interp(table, idx, w))


def _grow_cells(smoke: bool):
    cells = [
        ("dense", "fp32"), ("dense", "int8"),
        ("tiered", "fp32"), ("tiered", "int8"),
        ("sharded-tiered", "fp32"),
    ]
    if not smoke:
        cells += [("dense", "fp8"), ("tiered", "fp8"),
                  ("sharded-tiered", "int8")]
    return cells


def measure(smoke: bool = False):
    import jax

    p = _params(smoke)
    rows = []
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    n_old, n_new = 2 ** p["log2"], 2 ** (p["log2"] + 1)

    # ---- growth: pause + pre-growth-point equivalence per cell
    for placement, storage in _grow_cells(smoke):
        cfg = _make_cfg(placement, storage, p)
        params, _ = lram.lram_init(key, cfg)
        q = _query_points(rng, p["queries"], cfg.torus_spec)
        y_pre = _interp_at(cfg, params["values"], q)
        t0 = time.perf_counter()
        params2, cfg2 = memctl.grow(params, cfg, n_new)
        pause_us = 1e6 * (time.perf_counter() - t0)
        y_post = _interp_at(cfg2, params2["values"], q)
        err = float(np.abs(y_post - y_pre).max())
        assert err <= 1e-5, (
            f"grow {placement}/{storage}: pre-growth points drifted "
            f"{err:.3e}"
        )
        name = f"lifecycle_grow_{placement}_{storage}".replace("-", "_")
        rows.append((name, pause_us,
                     f"err={err:.2e} n={n_old}->{n_new}"))

    # ---- migration: dense -> tiered -> sharded-tiered -> dense
    cfg_d = _make_cfg("dense", "fp32", p)
    cfg_t = _make_cfg("tiered", "fp32", p)
    cfg_st = _make_cfg("sharded-tiered", "fp32", p)
    params, _ = lram.lram_init(key, cfg_d)
    table0 = np.asarray(params["values"])
    legs = [("dense", cfg_d, "tiered", cfg_t),
            ("tiered", cfg_t, "sharded_tiered", cfg_st),
            ("sharded_tiered", cfg_st, "dense", cfg_d)]
    cur = dict(params)
    for src_name, src_cfg, dst_name, dst_cfg in legs:
        t0 = time.perf_counter()
        cur = memctl.migrate(cur, src_cfg, dst_cfg)
        pause_us = 1e6 * (time.perf_counter() - t0)
        rows.append((f"lifecycle_migrate_{src_name}_{dst_name}", pause_us,
                     f"n={n_old} m={p['m']}"))
    exact = np.array_equal(np.asarray(cur["values"]), table0)
    assert exact, "migration round trip is not payload-exact"
    rows.append(("lifecycle_migrate_roundtrip", 0.0,
                 f"exact={exact} dense->tiered->sharded_tiered->dense"))

    # ---- utilisation recovery after growth (telemetry)
    cfg = _make_cfg("dense", "fp32", p)
    params, _ = lram.lram_init(key, cfg)
    bins = 256
    tel = memctl.telemetry_init(n_old, rows_per_bin=n_old // bins)
    for _ in range(p["recovery_steps"]):
        q = _query_points(rng, p["queries"], cfg.torus_spec)
        idx, _ = lram.indices_and_weights(q, cfg.torus_spec, cfg.top_k)
        tel = memctl.telemetry_update(tel, idx)
    dead_pre = float(np.mean(np.asarray(tel["counts"]) == 0))
    params, cfg = memctl.grow(params, cfg, n_new)
    tel = memctl.grow_telemetry(tel, n_new)
    dead_post = float(np.mean(np.asarray(tel["counts"]) == 0))
    for _ in range(p["recovery_steps"]):
        q = _query_points(rng, p["queries"], cfg.torus_spec)
        idx, _ = lram.indices_and_weights(q, cfg.torus_spec, cfg.top_k)
        tel = memctl.telemetry_update(tel, idx)
    dead_end = float(np.mean(np.asarray(tel["counts"]) == 0))
    assert dead_end < dead_post, "grown rows never came alive"
    rows.append((
        "lifecycle_util_recovery", 0.0,
        f"dead_pre={dead_pre:.3f} post_growth={dead_post:.3f} "
        f"after_{p['recovery_steps']}_steps={dead_end:.3f}",
    ))
    return rows


def run(smoke: bool = False):
    return measure(smoke=smoke)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    for name, us, derived in run(smoke=args.smoke):
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
