"""Paper Table 5: memory utilisation % and KL(access || uniform).

Runs MLM inference through a (briefly trained) LRAM model with
`collect_access=True`: the weighted access histogram of the value table is
accumulated from the REAL mid-network query stream — the paper's exact
measurement (>98% of slots touched; KL ~ 1.6-2.5 nats).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, data, optim
from repro.launch.train import build_train_step
from repro.models import transformer

TRAIN_STEPS = 60


def _utilisation(cfg, params, state, dcfg, *, batches=24):
    n = cfg.lram.num_locations
    hist = np.zeros(n, np.float64)

    @jax.jit
    def probe(batch):
        _, _, _, acc = transformer.forward(
            params, state, batch, cfg, collect_access=True
        )
        return acc

    for i in range(batches):
        batch = jax.tree.map(
            jnp.asarray, data.get_batch(dcfg, step=5_000_000 + i)
        )
        acc = probe(batch)
        for idx, w in acc.values():
            np.add.at(hist, np.asarray(idx).reshape(-1),
                      np.asarray(w, dtype=np.float64).reshape(-1))
    used = float((hist > 0).mean())
    p = hist / max(hist.sum(), 1e-12)
    nz = p[p > 0]
    kl = float((nz * np.log(nz * hist.size)).sum())
    return used, kl


def run() -> list[tuple[str, float, str]]:
    cfg = configs.get_smoke_config("lram-bert-small")
    dcfg = data.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=64,
        kind="facts", objective="mlm",
    )
    params, state = transformer.init(jax.random.PRNGKey(0), cfg)
    used0, kl0 = _utilisation(cfg, params, state, dcfg, batches=8)

    # brief training (the paper measures a trained model)
    opt_cfg = optim.OptimConfig(lr=3e-4, memory_lr_mult=10.0)
    step_fn = build_train_step(cfg, opt_cfg)
    opt_state = optim.adam_init(params)
    resid = jnp.zeros(())
    for step in range(TRAIN_STEPS):
        batch = jax.tree.map(jnp.asarray, data.get_batch(dcfg, step=step))
        params, opt_state, state, resid, _ = step_fn(
            params, opt_state, state, resid, batch
        )
    used1, kl1 = _utilisation(cfg, params, state, dcfg)

    return [
        ("table5.memory_locations", 0.0,
         f"{cfg.lram.num_locations} (reduced config; paper 2^18..2^22)"),
        ("table5.usage_pct_untrained", 0.0, f"{100*used0:.2f}%"),
        ("table5.usage_pct_trained", 0.0,
         f"{100*used1:.2f}% of slots touched (paper: 98.5-99.99%)"),
        ("table5.kl_from_uniform_trained", 0.0,
         f"{kl1:.3f} nats (paper: 1.57-2.52; untrained {kl0:.3f})"),
    ]
