"""Paper Table 5: memory utilisation % and KL(access || uniform).

Runs MLM inference through a (briefly trained) LRAM model with
`collect_access=True`: the weighted access histogram of the value table is
accumulated from the REAL mid-network query stream — the paper's exact
measurement (>98% of slots touched; KL ~ 1.6-2.5 nats).

The access stream also feeds the jit-safe usage counters
(`repro.memctl.telemetry`, the same device-side segment-sum the
`--telemetry` train step carries), so the hot/cold/dead utilisation rows
ride the benchmark output — and, when the observability layer is armed
(`--metrics-dir`, or `benchmarks.run --metrics-dir`), land in the JSONL
event log and Prometheus textfile through `repro.obs`.

    PYTHONPATH=src python -m benchmarks.run table5 --smoke
    PYTHONPATH=src python -m benchmarks.table5_utilisation --smoke \
        --metrics-dir /tmp/bench-metrics
"""

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, data, memctl, obs, optim
from repro.launch.train import build_train_step
from repro.models import transformer

TRAIN_STEPS = 60
SMOKE_TRAIN_STEPS = 12
SMOKE_BATCHES = 6


def _utilisation(cfg, params, state, dcfg, *, batches=24):
    """(used_frac, kl, telemetry) from the real mid-network access stream.

    The per-location histogram (float64, host) reproduces the paper's
    numbers; the telemetry pytree accumulates the same indices through
    `memctl.telemetry_update` inside jit — the device-side counter path
    the hot/cold/dead rows come from.
    """
    n = cfg.lram.num_locations
    hist = np.zeros(n, np.float64)
    tel = memctl.telemetry_init(n)

    @jax.jit
    def probe(batch, tel):
        _, _, _, acc = transformer.forward(
            params, state, batch, cfg, collect_access=True
        )
        for idx, _w in acc.values():
            tel = memctl.telemetry_update(tel, idx)
        return acc, tel

    for i in range(batches):
        batch = jax.tree.map(
            jnp.asarray, data.get_batch(dcfg, step=5_000_000 + i)
        )
        acc, tel = probe(batch, tel)
        for idx, w in acc.values():
            np.add.at(hist, np.asarray(idx).reshape(-1),
                      np.asarray(w, dtype=np.float64).reshape(-1))
    used = float((hist > 0).mean())
    p = hist / max(hist.sum(), 1e-12)
    nz = p[p > 0]
    kl = float((nz * np.log(nz * hist.size)).sum())
    return used, kl, jax.device_get(tel)


def run(smoke: bool = False):
    cfg = configs.get_smoke_config("lram-bert-small")
    dcfg = data.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=64,
        kind="facts", objective="mlm",
    )
    params, state = transformer.init(jax.random.PRNGKey(0), cfg)
    used0, kl0, _ = _utilisation(cfg, params, state, dcfg, batches=8)

    # brief training (the paper measures a trained model)
    opt_cfg = optim.OptimConfig(lr=3e-4, memory_lr_mult=10.0)
    step_fn = build_train_step(cfg, opt_cfg)
    opt_state = optim.adam_init(params)
    resid = jnp.zeros(())
    for step in range(SMOKE_TRAIN_STEPS if smoke else TRAIN_STEPS):
        batch = jax.tree.map(jnp.asarray, data.get_batch(dcfg, step=step))
        params, opt_state, state, resid, _ = step_fn(
            params, opt_state, state, resid, batch
        )
    used1, kl1, tel = _utilisation(
        cfg, params, state, dcfg,
        batches=SMOKE_BATCHES if smoke else 24,
    )

    # hot/cold/dead rows from the drained device counters, mirrored into
    # the obs registry (no-ops unless a caller armed it)
    util_rows = memctl.utilisation_report(tel, prefix="table5.util")
    s = memctl.utilisation_summary(tel)
    obs.gauge("table5.util_dead_frac").set(s["dead_frac"])
    obs.gauge("table5.util_hot_mass").set(s["hot_mass"])
    obs.gauge("table5.util_cold_frac").set(s["cold_frac"])
    obs.gauge("table5.usage_frac_trained").set(round(used1, 4))

    return [
        ("table5.memory_locations", 0.0,
         f"{cfg.lram.num_locations} (reduced config; paper 2^18..2^22)"),
        ("table5.usage_pct_untrained", 0.0, f"{100*used0:.2f}%"),
        ("table5.usage_pct_trained", 0.0,
         f"{100*used1:.2f}% of slots touched (paper: 98.5-99.99%)"),
        ("table5.kl_from_uniform_trained", 0.0,
         f"{kl1:.3f} nats (paper: 1.57-2.52; untrained {kl0:.3f})"),
        *[(name, us, derived) for name, us, derived in util_rows],
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep (fewer train steps / probe batches)")
    ap.add_argument("--json", action="store_true",
                    help="emit the benchmark summary document")
    ap.add_argument("--metrics-dir", default="",
                    help="arm repro.obs: utilisation gauges land in "
                         "<dir>/metrics.jsonl + <dir>/metrics.prom")
    args = ap.parse_args(argv)
    if args.metrics_dir:
        obs.configure(metrics_dir=args.metrics_dir)
    rows = run(smoke=args.smoke)
    if args.metrics_dir:
        obs.flush()
    if args.json:
        print(json.dumps({
            "rows": [[n, us, d] for n, us, d in rows],
            "tables": ["table5_utilisation"],
            "smoke": args.smoke,
            "metrics": obs.metrics_doc(),
        }))
    else:
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
