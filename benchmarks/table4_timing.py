"""Paper Table 4 / Figure 3: wall-clock scaling of the memory layer.

Two claims, both testable on CPU (absolute times differ from the paper's
RTX 3090, the SHAPES are the claims):

  1. LRAM forward time is ~CONSTANT in memory size N (O(1) random access);
     PKM grows ~sqrt(N); a dense layer of equal param count grows ~N.
  2. LRAM cost grows ~w^2 with width (the dense projections dominate), so
     at large w it crosses below the dense 2-layer block (paper Table 4).
"""

import time

import jax
import numpy as np

from repro.core import lram, pkm


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else None
    times = []
    for _ in range(iters):
        t0 = time.time()
        out = f(*args)
        jax.block_until_ready(out)
        times.append(time.time() - t0)
    return float(np.median(times))


def run() -> list[tuple[str, float, str]]:
    rows = []
    batch = 256
    key = jax.random.PRNGKey(0)

    # ---- claim 1: forward time vs N ----------------------------------------
    lram_times = {}
    for log2 in (16, 18, 20):
        cfg = lram.LRAMConfig(log2_locations=log2, m=64, heads=8,
                              query_norm="rms")
        params, state = lram.lram_init(key, cfg)
        x = jax.random.normal(key, (batch, cfg.in_dim))
        f = jax.jit(lambda p, x, cfg=cfg, state=state:
                    lram.lram_apply(p, state, x, cfg)[0])
        t = _time(f, params, x)
        lram_times[log2] = t
        rows.append((f"table4.lram_fwd_N2^{log2}",
                     1e6 * t / batch, f"{t*1e3:.2f} ms/batch{batch}"))
    flat = lram_times[20] / max(lram_times[16], 1e-9)
    rows.append((
        "table4.lram_O1_in_N", 0.0,
        f"t(2^20)/t(2^16) = {flat:.2f} (paper: ~1.0, O(1) scaling; "
        f"16x more parameters for free)",
    ))

    pkm_times = {}
    for n_keys in (128, 256, 512):
        cfg = pkm.PKMConfig(n_keys=n_keys, heads=8, key_dim=64,
                            value_dim=512, top_k=32, query_norm="none")
        params, state = pkm.pkm_init(key, 512, cfg)
        x = jax.random.normal(key, (batch, 512))
        f = jax.jit(lambda p, x, cfg=cfg, state=state:
                    pkm.pkm_apply(p, state, x, cfg)[0])
        t = _time(f, params, x)
        pkm_times[n_keys] = t
        rows.append((f"table4.pkm_fwd_N{n_keys**2}",
                     1e6 * t / batch, f"{t*1e3:.2f} ms/batch{batch}"))
    rows.append((
        "table4.pkm_sqrtN_growth", 0.0,
        f"t(512^2)/t(128^2) = "
        f"{pkm_times[512]/max(pkm_times[128],1e-9):.2f} "
        "(PKM cost grows with sqrt(N); LRAM stays flat)",
    ))

    # ---- claim 2: LRAM vs dense across width -------------------------------
    for w in (256, 512, 1024):
        dcfg = lram.memffn_config(w, 16, query_norm="rms")
        mp, ms = lram.memffn_init(key, w, dcfg)
        x = jax.random.normal(key, (batch, w))
        f_mem = jax.jit(lambda p, x, c=dcfg, s=ms:
                        lram.memffn_apply(p, s, x, c)[0])
        t_mem = _time(f_mem, mp, x)

        wk = jax.random.normal(key, (w, 4 * w)) / np.sqrt(w)
        wo = jax.random.normal(key, (4 * w, w)) / np.sqrt(4 * w)
        f_dense = jax.jit(
            lambda x, wk=wk, wo=wo: jax.nn.gelu(x @ wk) @ wo
        )
        t_dense = _time(f_dense, x)
        rows.append((
            f"table4.width{w}", 1e6 * t_mem / batch,
            f"lram {t_mem*1e3:.2f} ms | dense {t_dense*1e3:.2f} ms | "
            f"ratio {t_mem/max(t_dense,1e-9):.2f}",
        ))
    return rows
