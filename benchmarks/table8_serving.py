"""Table 8 (beyond-paper): serving throughput — static vs continuous batching.

Replays the same mixed-length request trace (random prompt and generation
lengths, the head-of-line-blocking regime) through `repro.serving` in both
scheduling modes and reports tokens/sec with p50/p99 per-step latency, at
each offered load (requests/sec; 0 = closed loop, everything queued at
t=0).  Compilation is amortised by a warmup replay per engine, so the
rows measure steady-state scheduling, not jit time.

    PYTHONPATH=src python -m benchmarks.run table8
    PYTHONPATH=src python -m benchmarks.table8_serving --smoke

The `--smoke` form is the acceptance check: it additionally asserts that
continuous batching sustains at least the static-batch throughput on the
closed-loop trace.

A multi-tenant row rides along: the same closed-loop trace with requests
spread over a tenant pool and per-tenant memory overlays attached
(`repro.serving.overlay`), reporting overlay hit-rate and bytes/tenant
next to the throughput.

An observability-overhead row (`serving_obs_load0`) replays the continuous
closed-loop trace with the metrics registry and span tracer armed
(`repro.obs`), so the cost of live telemetry is tracked as its own
benchmark row instead of silently taxing the metrics-off rows.
"""

from __future__ import annotations

import argparse
import json
import tempfile

import jax
import numpy as np

from repro import configs, obs
from repro.models import transformer
from repro.serving import EngineConfig, ServeEngine, synthetic_trace

ARCH = "lram-tiered"
SLOTS = 4
MAX_PROMPT, MAX_GEN = 12, 24
NUM_REQUESTS = 16
RATES = (0.0, 4.0)            # requests/sec; 0 = closed loop
SMOKE_REQUESTS = 8
SMOKE_RATES = (0.0,)
TENANTS = 4                   # multi-tenant row: tenant pool size
OVERLAY_ROWS = 8              # per-tenant overlay capacity (rows/layer)


def _measure(smoke: bool):
    cfg = configs.get_smoke_config(ARCH)
    params, state = transformer.init(jax.random.PRNGKey(0), cfg)
    num_requests = SMOKE_REQUESTS if smoke else NUM_REQUESTS
    rates = SMOKE_RATES if smoke else RATES
    max_gen = MAX_GEN // 2 if smoke else MAX_GEN
    rows, tps = [], {}
    for rate in rates:
        trace = synthetic_trace(
            np.random.default_rng(0), num_requests,
            vocab_size=cfg.vocab_size, max_prompt=MAX_PROMPT,
            max_gen=max_gen, rate=rate, mixed=True,
        )
        for mode in ("static", "continuous"):
            engine = ServeEngine(params, state, cfg, EngineConfig(
                slots=SLOTS, max_len=MAX_PROMPT + max_gen, mode=mode,
            ))
            engine.run(trace)          # warmup: compile every bucket + step
            report = engine.run(trace)
            tps[(mode, rate)] = report.tokens_per_sec
            us = (1e6 / report.tokens_per_sec if report.tokens_per_sec
                  else 0.0)
            rows.append((
                f"serving_{mode}_load{rate:g}", round(us, 3),
                f"tokens_per_sec={report.tokens_per_sec:.1f} "
                f"p50_ms={report.p50_ms():.2f} p99_ms={report.p99_ms():.2f} "
                f"steps={len(report.step_s)}"
                + (f" hit={report.cache['hit_rate']}" if report.cache
                   else ""),
            ))
    # multi-tenant overlay row: the closed-loop trace spread over a
    # tenant pool, per-tenant copy-on-write overlays attached per slot
    trace = synthetic_trace(
        np.random.default_rng(0), num_requests,
        vocab_size=cfg.vocab_size, max_prompt=MAX_PROMPT,
        max_gen=max_gen, rate=0.0, mixed=True, tenants=TENANTS,
    )
    engine = ServeEngine(params, state, cfg, EngineConfig(
        slots=SLOTS, max_len=MAX_PROMPT + max_gen,
        overlay_rows=OVERLAY_ROWS,
    ))
    engine.run(trace)
    report = engine.run(trace)
    us = 1e6 / report.tokens_per_sec if report.tokens_per_sec else 0.0
    o = report.overlay
    rows.append((
        "serving_multitenant_load0", round(us, 3),
        f"tokens_per_sec={report.tokens_per_sec:.1f} "
        f"tenants={o['tenants']} overlay_rows={OVERLAY_ROWS} "
        f"overlay_hit_rate={o['hit_rate']} "
        f"bytes_per_tenant={o['bytes_per_tenant']} "
        f"writebacks={o['writebacks']}",
    ))
    tps[("multitenant", 0.0)] = report.tokens_per_sec

    # observability-overhead row: the continuous closed-loop trace again,
    # now with the metrics registry + span tracer armed (JSONL streaming
    # to a scratch dir) — the metrics-on serving cost as its own row
    trace = synthetic_trace(
        np.random.default_rng(0), num_requests,
        vocab_size=cfg.vocab_size, max_prompt=MAX_PROMPT,
        max_gen=max_gen, rate=0.0, mixed=True,
    )
    engine = ServeEngine(params, state, cfg, EngineConfig(
        slots=SLOTS, max_len=MAX_PROMPT + max_gen, mode="continuous",
    ))
    was_enabled = obs.enabled()
    if not was_enabled:
        obs.configure(metrics_dir=tempfile.mkdtemp(prefix="obs-bench-"))
    try:
        engine.run(trace)          # warmup
        report = engine.run(trace)
        obs.flush()
    finally:
        if not was_enabled:
            obs.disable()
    tps[("obs", 0.0)] = report.tokens_per_sec
    us = 1e6 / report.tokens_per_sec if report.tokens_per_sec else 0.0
    base = tps[("continuous", 0.0)]
    overhead = (base / report.tokens_per_sec
                if report.tokens_per_sec else 0.0)
    rows.append((
        "serving_obs_load0", round(us, 3),
        f"tokens_per_sec={report.tokens_per_sec:.1f} "
        f"overhead_x={overhead:.3f} vs metrics-off continuous "
        f"({base:.1f} tok/s) "
        f"p50_ms={report.p50_ms():.2f} p99_ms={report.p99_ms():.2f}",
    ))
    return rows, tps


def run(smoke: bool = False):
    return _measure(smoke)[0]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace + assert continuous >= static "
                         "throughput on the closed-loop trace")
    ap.add_argument("--json", action="store_true",
                    help="emit the benchmark summary document")
    args = ap.parse_args(argv)
    rows, tps = _measure(args.smoke)
    if args.json:
        print(json.dumps({
            "rows": [[n, us, d] for n, us, d in rows],
            "tables": ["table8_serving"],
            "smoke": args.smoke,
        }))
    else:
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}")
    if args.smoke:
        cont, stat = tps[("continuous", 0.0)], tps[("static", 0.0)]
        ok = cont >= stat
        print(f"# smoke check: continuous {cont:.1f} tok/s vs "
              f"static {stat:.1f} tok/s -> {'OK' if ok else 'FAIL'}")
        if not ok:
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
