"""Paper Table 1: lattice kernel-support statistics (E8 vs Z8).

Monte Carlo over the real lookup pipeline for E8 (2*E8, kernel radius
sqrt 8) and the analytic ball-volume identity for the averages:

    avg support = V_8(r_kernel) / det = pi^4 r^8 / 24 / 256

Z8 at the same density ((2Z)^8, det 256) with the paper's kernel-radius rule
(sqrt 2 x covering radius -> r = 4) gives avg 1039 — the 16x access-count
advantage of E8 the paper claims.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lattice


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    t0 = time.time()
    n = 20_000 if smoke else 100_000
    q = rng.uniform(0, 16, size=(n, 8)).astype(np.float32)
    f = jax.jit(lattice.neighbors_and_weights)
    counts, sums = [], []
    for i in range(0, len(q), 20_000):
        _, w = f(jnp.asarray(q[i : i + 20_000]))
        w = np.asarray(w)
        counts.append((w > 0).sum(1))
        sums.append(w.sum(1))
    counts = np.concatenate(counts)
    sums = np.concatenate(sums)
    us = 1e6 * (time.time() - t0) / len(q)

    e8_avg_analytic = np.pi**4 * 8.0**4 / 24.0 / 256.0          # 64.94
    z8_avg_analytic = np.pi**4 * 4.0**8 / 24.0 / 256.0          # 1039
    rows = [
        ("table1.e8_support_min_mc", us, f"{counts.min()} (paper 45)"),
        ("table1.e8_support_avg_mc", us,
         f"{counts.mean():.2f} (paper 64.94; analytic {e8_avg_analytic:.2f})"),
        ("table1.e8_support_max_mc", us, f"{counts.max()} (paper max 121)"),
        ("table1.z8_support_avg_analytic", 0.0,
         f"{z8_avg_analytic:.0f} (paper 1039; E8 advantage "
         f"{z8_avg_analytic / e8_avg_analytic:.1f}x)"),
        ("table1.e8_weight_sum_min", us,
         f"{sums.min():.4f} (paper bound 0.851)"),
        ("table1.e8_weight_sum_max", us, f"{sums.max():.4f} (paper 1)"),
        ("table1.candidates_in_F", 0.0,
         f"{lattice.candidate_table().shape[0]} (paper 232)"),
    ]
    return rows
