"""Table 7 (beyond-paper): quantized value tables — bytes/entry, accuracy,
and per-lookup latency vs the fp32 tiered path.

Runs the same drifting-hot-set access stream as table6 over (a) the dense
fp32 reference gather, (b) the fp32 tiered store, and (c) the quantized
tiered stores (int8 / fp8), reporting for each: per-lookup latency,
effective bytes per table entry (payload + per-row scales), host->device
fill traffic, and the max abs output delta vs the fp32 reference — which
must sit inside the documented `repro.quant.max_abs_error_bound`.

    PYTHONPATH=src python -m benchmarks.run table7        # harness row form
    PYTHONPATH=src python -m benchmarks.table7_quant --smoke

The `--smoke` form is the acceptance check: it additionally prints a
summary asserting >=3.5x bytes/entry reduction and int8-tiered latency no
worse than fp32-tiered.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.core import lram
from repro.memstore import TieredSpec, TieredValueStore

M = 64
TOP_K = 32


def _params(smoke: bool):
    if smoke:
        return dict(num_rows=2**14, shard_rows=512, batch=128,
                    steps=8, warmup=2)
    return dict(num_rows=2**16, shard_rows=2048, batch=256,
                steps=12, warmup=3)


def _stream(rng, steps, num_rows, batch):
    """table6's decode-like pattern: a drifting hot window (cache-friendly)
    so fills — the traffic quantization shrinks — stay on the clock."""
    hot_span = num_rows // 8
    center = 0
    for _ in range(steps):
        center = (center + rng.integers(0, num_rows // 16)) % num_rows
        yield ((center + rng.integers(0, hot_span, (batch, TOP_K)))
               % num_rows).astype(np.int32)


def _time_stream(gather, rng, p):
    times = []
    for t, idx in enumerate(_stream(rng, p["steps"], p["num_rows"],
                                    p["batch"])):
        w = (rng.normal(size=idx.shape).astype(np.float32) / TOP_K)
        t0 = time.perf_counter()
        out = gather(idx, w)
        jax.block_until_ready(out)
        if t >= p["warmup"]:
            times.append(time.perf_counter() - t0)
    return 1e6 * float(np.median(times))  # median: robust to CPU jitter


def _accuracy(dense, store_or_table, rng, p, kind):
    """Max abs delta vs the fp32 gather on a fresh index set, with the
    documented bound it must respect."""
    idx = rng.integers(0, p["num_rows"], size=(64, TOP_K)).astype(np.int32)
    w = rng.normal(size=idx.shape).astype(np.float32) / TOP_K
    want = np.einsum("...k,...km->...m", w, dense[idx])
    if isinstance(store_or_table, TieredValueStore):
        got = np.asarray(store_or_table.gather(idx, w))
        scale = np.concatenate(
            [store_or_table.shard_scale_host(i)
             for i in range(store_or_table.num_shards)]
        )
    else:
        got = np.asarray(quant.gather_interp_quant(
            store_or_table, jnp.asarray(idx), jnp.asarray(w)))
        scale = np.asarray(store_or_table.scale)
    err = float(np.abs(got - want).max())
    bound = quant.max_abs_error_bound(scale, w, kind)
    return err, bound


def measure(smoke: bool = False):
    p = _params(smoke)
    rng = np.random.default_rng(0)
    dense = rng.normal(size=(p["num_rows"], M)).astype(np.float32) * 0.02
    num_shards = p["num_rows"] // p["shard_rows"]
    slots = max(2, num_shards // 4)  # 25% resident: fills dominate
    rows, summary = [], {}

    dense_dev = jnp.asarray(dense)
    ref = jax.jit(lram.gather_interp)
    us = _time_stream(
        lambda i, w: ref(dense_dev, jnp.asarray(i), jnp.asarray(w)),
        np.random.default_rng(1), p,
    )
    rows.append(("quant_dense_fp32", us, f"bytes_per_entry={4 * M}"))

    for kind in ("none", "int8", "fp8"):
        store = TieredValueStore.from_dense(
            dense, TieredSpec(shard_rows=p["shard_rows"], cache_slots=slots,
                              quant=kind)
        )
        store.warm()
        store.reset_stats()
        us = _time_stream(store.gather, np.random.default_rng(1), p)
        bpe = store.bytes_per_entry()
        derived = (
            f"bytes_per_entry={bpe} hit={store.hit_rate():.3f} "
            f"fill_mb={store.stats['fill_bytes'] / 2**20:.2f}"
        )
        if kind != "none":
            err, bound = _accuracy(dense, store, np.random.default_rng(2),
                                   p, kind)
            derived += f" max_err={err:.2e} bound={bound:.2e}"
            assert err <= bound + 1e-6, (kind, err, bound)
        rows.append((f"quant_tiered_{kind if kind != 'none' else 'fp32'}",
                     us, derived))
        summary[kind] = {"us": us, "bytes_per_entry": bpe,
                         "fill_bytes": store.stats["fill_bytes"]}
    return rows, summary


def run():
    return measure(smoke=False)[0]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + acceptance summary")
    args = ap.parse_args(argv)
    rows, summary = measure(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    fp32, q8 = summary["none"], summary["int8"]
    reduction = fp32["bytes_per_entry"] / q8["bytes_per_entry"]
    fill_reduction = (fp32["fill_bytes"] / q8["fill_bytes"]
                      if q8["fill_bytes"] else float("inf"))
    print(f"# bytes/entry: {fp32['bytes_per_entry']} -> "
          f"{q8['bytes_per_entry']} ({reduction:.2f}x reduction)")
    print(f"# fill traffic: {fill_reduction:.2f}x reduction")
    print(f"# latency: fp32-tiered {fp32['us']:.1f}us vs "
          f"int8-tiered {q8['us']:.1f}us")
    assert reduction >= 3.5, f"bytes/entry reduction {reduction:.2f}x < 3.5x"
    # latency acceptance with a noise margin: the quantized path must not
    # be meaningfully slower than the fp32 tiered path it replaces
    assert q8["us"] <= 1.10 * fp32["us"], (
        f"int8 tiered {q8['us']:.1f}us > fp32 tiered {fp32['us']:.1f}us"
    )
    print("# OK: >=3.5x bytes/entry, int8 latency <= fp32 tiered")


if __name__ == "__main__":
    main()
