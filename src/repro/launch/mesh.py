"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax init,
and smoke tests must keep seeing exactly 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Best-effort mesh over whatever devices exist (tests / CPU driver)."""
    n = jax.device_count()
    if shape is None:
        model = 1
        for cand in (4, 2):
            if n % cand == 0 and n >= cand * 2:
                model = cand
                break
        shape = (n // model, model)
    return jax.make_mesh(shape, axes)
