import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
# init).  This module is the ONLY place the 512-device placeholder platform
# is created; smoke tests and benchmarks see 1 device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

For each cell this proves the distribution config is coherent end-to-end:
jit(step).lower(<ShapeDtypeStructs with NamedShardings>).compile() must
succeed on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh, and the
artifact's memory_analysis/cost_analysis + the optimized-HLO collective scan
are written to artifacts/dryrun/*.json for the roofline (§Roofline).

train_4k lowers train_step (fwd+bwd+Adam, donated); prefill_32k lowers
prefill (forward + cache build); decode_32k / long_500k lower decode_step
(one token against a seq_len cache).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs, optim
from repro.analysis import hlo as hlo_lib
from repro.configs import shapes as shapes_lib
from repro.distributed import sharding
from repro.launch.mesh import make_production_mesh
from repro.models import transformer

ARTIFACT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "artifacts", "dryrun",
)


def _sds(tree, mesh, spec_tree):
    return jax.tree.map(
        lambda sd, spec: jax.ShapeDtypeStruct(
            sd.shape, sd.dtype, sharding=NamedSharding(mesh, spec)
        ),
        tree, spec_tree,
    )


def _replicated(tree, mesh):
    return jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct(
            sd.shape, sd.dtype, sharding=NamedSharding(mesh, P())
        ),
        tree,
    )


def build_lowerable(cfg, shape_name: str, mesh):
    """Returns (fn, example_args) ready for jit(fn).lower(*args)."""
    cell = shapes_lib.SHAPES[shape_name]
    specs = shapes_lib.input_specs(cfg, shape_name)
    params_sh, state_sh = jax.eval_shape(
        lambda: transformer.init(jax.random.PRNGKey(0), cfg)
    )
    pspecs = sharding.param_pspecs(params_sh, mesh, model_cfg=cfg)
    params_in = _sds(params_sh, mesh, pspecs)
    state_in = _replicated(state_sh, mesh)
    bspec = sharding.batch_pspec(mesh)

    if cell.mode == "train":
        opt_cfg = optim.OptimConfig()
        opt_sh = jax.eval_shape(optim.adam_init, params_sh)
        opt_in = {
            "mu": _sds(opt_sh["mu"], mesh, pspecs),
            "nu": _sds(opt_sh["nu"], mesh, pspecs),
            "step": jax.ShapeDtypeStruct(
                (), jnp.int32, sharding=NamedSharding(mesh, P())
            ),
        }
        batch_in = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(
                sd.shape, sd.dtype,
                sharding=NamedSharding(
                    mesh, P(*(bspec + (None,) * (len(sd.shape) - 1)))
                    if len(sd.shape) != 3 or sd.shape[0] != 3
                    else P(None, *(bspec + (None,)))  # (3,B,S) positions
                ),
            ),
            specs["batch"],
        )

        def train_step(params, opt_state, model_state, batch):
            (loss, (new_state, metrics)), grads = jax.value_and_grad(
                transformer.loss_fn, has_aux=True
            )(params, model_state, batch, cfg, train=True)
            new_params, new_opt, stats = optim.adam_update(
                grads, opt_state, params, opt_cfg
            )
            return new_params, new_opt, new_state, {
                "loss": loss, **stats
            }

        fn = jax.jit(train_step, donate_argnums=(0, 1))
        return fn, (params_in, opt_in, state_in, batch_in)

    if cell.mode == "prefill":
        batch_in = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(
                sd.shape, sd.dtype,
                sharding=NamedSharding(
                    mesh, P(*(bspec + (None,) * (len(sd.shape) - 1)))
                    if len(sd.shape) != 3 or sd.shape[0] != 3
                    else P(None, *(bspec + (None,)))
                ),
            ),
            specs["batch"],
        )

        def prefill_step(params, model_state, batch):
            return transformer.prefill(
                params, model_state, batch, cfg, max_len=cell.seq_len
            )

        fn = jax.jit(prefill_step)
        return fn, (params_in, state_in, batch_in)

    # decode
    cache_sh = specs["cache"]
    cache_specs_tree = sharding.cache_pspecs(cache_sh, cfg, mesh)
    cache_in = _sds(cache_sh, mesh, cache_specs_tree)
    tokens_in = jax.ShapeDtypeStruct(
        specs["tokens"].shape, specs["tokens"].dtype,
        sharding=NamedSharding(
            mesh,
            P(bspec[0] if specs["tokens"].shape[0] % np.prod(
                [mesh.shape[a] for a in (
                    bspec[0] if isinstance(bspec[0], tuple) else (bspec[0],)
                )]) == 0 else None, None),
        ),
    )
    pos_in = jax.ShapeDtypeStruct(
        (), jnp.int32, sharding=NamedSharding(mesh, P())
    )

    def serve_step(params, model_state, tokens, pos, cache):
        return transformer.decode_step(
            params, model_state, tokens, pos, cache, cfg
        )

    fn = jax.jit(serve_step, donate_argnums=(4,))
    return fn, (params_in, state_in, tokens_in, pos_in, cache_in)


def _compile_and_measure(cfg, shape_name, mesh, save_hlo_path=None):
    t0 = time.time()
    fn, args = build_lowerable(cfg, shape_name, mesh)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}
    hlo_text = compiled.as_text()
    coll = hlo_lib.parse_collectives(hlo_text)
    if save_hlo_path:
        with open(save_hlo_path, "w") as f:
            f.write(hlo_text)
    return {
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": cost.get("flops"),
        "bytes_per_device": cost.get("bytes accessed"),
        "memory_analysis": mem_info,
        "collective_counts": coll.counts,
        "collective_wire_bytes": coll.wire_bytes,
        "total_wire_bytes_per_device": coll.total_wire_bytes,
        "hlo_lines": hlo_text.count("\n"),
    }


def _depth_variant(cfg, arch: str, n: int, lram_log2: int):
    """A depth-n (units for hybrid) unrolled variant of the same cell."""
    over = {"scan_layers": False}
    if cfg.family == "hybrid":
        over["num_layers"] = n * cfg.hybrid_pattern
    elif cfg.family == "encdec":
        over["num_layers"] = n
        over["encoder_layers"] = n
    else:
        over["num_layers"] = n
    small = configs.get_config(arch, **over)
    if lram_log2:
        small = configs.with_lram(small, lram_log2, layer=1)
    return small


def _full_depth_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid_pattern
    return cfg.num_layers


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             lram_log2: int = 0, save_hlo: bool = False,
             unroll: bool = True, overrides: dict | None = None) -> dict:
    """One dry-run cell.

    Always: full-depth *scanned* lower+compile on the target mesh — this is
    the partitioning proof and the memory_analysis source (scan is also the
    deployment configuration).  Additionally (single-pod roofline cells):
    two reduced-depth *unrolled* compiles; XLA's cost_analysis counts a
    while-loop body once, so exact FLOP/byte/collective totals come from
    the linear depth extrapolation  F(L) = F(L1) + (L-L1)*(F(L2)-F(L1))/(L2-L1).
    """
    mesh_name = "multi" if multi_pod else "single"
    cfg = configs.get_config(arch, **(overrides or {}))
    if lram_log2:
        cfg = configs.with_lram(cfg, lram_log2)
    result = {
        "arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
        "status": "ok",
    }
    reason = shapes_lib.skip_reason(cfg, shape_name)
    if reason:
        result.update(status="skipped", reason=reason)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.distributed import context
    context.set_mesh(mesh)
    hlo_path = None
    if save_hlo:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        hlo_path = os.path.join(
            ARTIFACT_DIR, f"{cfg.name}__{shape_name}__{mesh_name}.hlo.txt")
    full = _compile_and_measure(cfg, shape_name, mesh, hlo_path)
    result.update({
        "devices": int(np.prod(list(mesh.shape.values()))),
        "mesh_shape": dict(mesh.shape),
        "scanned": full,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    })

    if unroll and not multi_pod:
        l1, l2 = (1, 2) if cfg.family == "hybrid" else (2, 4)
        m1 = _compile_and_measure(
            _depth_variant(cfg, arch, l1, lram_log2), shape_name, mesh)
        m2 = _compile_and_measure(
            _depth_variant(cfg, arch, l2, lram_log2), shape_name, mesh)
        lf = _full_depth_units(cfg)

        def extrap(a, b):
            if a is None or b is None:
                return None
            return a + (b - a) / (l2 - l1) * (lf - l1)

        wire_kinds = set(m1["collective_wire_bytes"]) | set(
            m2["collective_wire_bytes"])
        result["extrapolated"] = {
            "from_depths": [l1, l2, lf],
            "flops_per_device": extrap(m1["flops_per_device"],
                                       m2["flops_per_device"]),
            "bytes_per_device": extrap(m1["bytes_per_device"],
                                       m2["bytes_per_device"]),
            "collective_wire_bytes": {
                k: extrap(m1["collective_wire_bytes"].get(k, 0.0),
                          m2["collective_wire_bytes"].get(k, 0.0))
                for k in sorted(wire_kinds)
            },
            "total_wire_bytes_per_device": extrap(
                m1["total_wire_bytes_per_device"],
                m2["total_wire_bytes_per_device"]),
            "depth_compiles": {"l1": m1, "l2": m2},
        }
    return result


def _artifact_path(arch, shape, mesh_name, lram_log2=0):
    name = arch if not lram_log2 else f"{arch}+lram{lram_log2}"
    return os.path.join(ARTIFACT_DIR, f"{name}__{shape}__{mesh_name}.json")


def main(argv=None):
    global ARTIFACT_DIR
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None,
                   choices=list(shapes_lib.SHAPES) + [None])
    p.add_argument("--mesh", default="single",
                   choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--lram-log2", type=int, default=0,
                   help="insert the paper's LRAM block (memory slots 2^N)")
    p.add_argument("--force", action="store_true",
                   help="recompute cells that already have artifacts")
    p.add_argument("--scan", action="store_true",
                   help="keep lax.scan over layers (faster compile, but "
                        "cost_analysis undercounts loop bodies)")
    p.add_argument("--save-hlo", action="store_true")
    p.add_argument("--out", default=ARTIFACT_DIR)
    args = p.parse_args(argv)

    ARTIFACT_DIR = args.out
    os.makedirs(ARTIFACT_DIR, exist_ok=True)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = [(a, s) for a in configs.ARCHS
                 for s in shapes_lib.SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        for multi_pod in meshes:
            mesh_name = "multi" if multi_pod else "single"
            path = _artifact_path(arch, shape, mesh_name, args.lram_log2)
            if os.path.exists(path) and not args.force:
                print(f"[skip-cached] {path}")
                continue
            print(f"[cell] {arch} x {shape} x {mesh_name} ...", flush=True)
            try:
                res = run_cell(arch, shape, multi_pod, args.lram_log2,
                               args.save_hlo, unroll=not args.scan)
            except Exception as e:
                res = {
                    "arch": arch, "shape": shape, "mesh": mesh_name,
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-3000:],
                }
                failures += 1
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            ex = res.get("extrapolated", {})
            print(f"  -> {res['status']} "
                  f"(compile {res.get('scanned', {}).get('compile_s', '-')}s"
                  f", flops/dev {ex.get('flops_per_device', '-')})",
                  flush=True)
    print(f"done; {failures} failures")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
