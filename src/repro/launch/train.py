"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch lram-bert-small --smoke --steps 200 --batch 8 --seq 64 \
        --ckpt-dir /tmp/ckpt --ckpt-every 50

Wires every substrate together: config -> init -> (mesh + GSPMD sharding if
>1 device) -> jitted train_step (loss + grad [+ compression] + Adam with the
paper's 10x memory-value LR) -> stateless data -> checkpoint/auto-resume ->
heartbeat/straggler log -> failure injection (--simulate-failure-at), after
which a relaunch resumes bit-exact from the latest valid checkpoint.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs, data, memctl, obs, optim
from repro.checkpoint import CheckpointManager
from repro.core import lookup
from repro.distributed import fault, sharding
from repro.launch import mesh as mesh_lib
from repro.models import transformer


def lram_segments(cfg) -> list[str]:
    """Segment names of the lram memory layers (telemetry keys)."""
    return [f"seg{si}" for si, seg in enumerate(transformer.layer_plan(cfg))
            if seg[0] == "memory" and seg[2] == "lram"]


def telemetry_rows_per_bin(num_locations: int, *, max_bins: int = 4096) -> int:
    """Coarsen per-row counters so the carried pytree stays <= max_bins
    bins (num_locations is a power of two, so this always divides)."""
    rpb = 1
    while num_locations // rpb > max_bins:
        rpb *= 2
    return rpb


def init_telemetry(cfg):
    """One usage-counter pytree per lram segment (the carried `tel`)."""
    n = cfg.lram.num_locations
    rpb = telemetry_rows_per_bin(n)
    return {name: memctl.telemetry_init(n, rows_per_bin=rpb)
            for name in lram_segments(cfg)}


def build_train_step(cfg, opt_cfg, mesh=None, compression="none",
                     telemetry=False):
    """The jitted step.  With `telemetry=True` the step carries a usage
    pytree (`tel`, from `init_telemetry`) like optimizer state: the loss
    runs with `collect_access=True` and each lram segment's access indices
    are scatter-added into its counters in-graph
    (`memctl.telemetry_update`) — the only mode that changes the traced
    computation; the plain step is byte-identical to the pre-obs one."""
    def _finish(loss, metrics, residual, grads, params, opt_state):
        if compression != "none":
            comp = {"kind": compression, "rho": 0.01, "residual": residual}
            grads, comp = optim.compress_gradients(grads, comp)
            residual = comp["residual"]
        new_params, new_opt, stats = optim.adam_update(
            grads, opt_state, params, opt_cfg
        )
        return new_params, new_opt, residual, \
            {**metrics, **stats, "loss": loss}

    if telemetry:
        def train_step(params, opt_state, model_state, residual, batch,
                       tel):
            (loss, (new_model_state, metrics, accesses)), grads = \
                jax.value_and_grad(transformer.loss_fn, has_aux=True)(
                    params, model_state, batch, cfg, train=True,
                    collect_access=True,
                )
            tel = {
                name: (memctl.telemetry_update(t, accesses[name][0])
                       if name in accesses else t)
                for name, t in tel.items()
            }
            new_params, new_opt, residual, metrics = _finish(
                loss, metrics, residual, grads, params, opt_state
            )
            return (new_params, new_opt, new_model_state, residual,
                    metrics, tel)
    else:
        def train_step(params, opt_state, model_state, residual, batch):
            (loss, (new_model_state, metrics)), grads = jax.value_and_grad(
                transformer.loss_fn, has_aux=True
            )(params, model_state, batch, cfg, train=True)
            new_params, new_opt, residual, metrics = _finish(
                loss, metrics, residual, grads, params, opt_state
            )
            return new_params, new_opt, new_model_state, residual, metrics

    if mesh is None:
        return jax.jit(train_step, donate_argnums=(0, 1))
    pspec = sharding.batch_pspec(mesh)
    batch_sh = NamedSharding(mesh, P(pspec[0] if len(pspec) else None))
    batch_in = jax.tree.map(lambda _: batch_sh, {"tokens": 0, "labels": 0})
    in_sh = (None, None, None, None, batch_in)
    if telemetry:
        in_sh = in_sh + (None,)
    return jax.jit(train_step, in_shardings=in_sh, donate_argnums=(0, 1))


def evaluate(params, model_state, cfg, dcfg, *, steps=4):
    losses, recalls = [], []
    table = data.make_fact_table(dcfg)
    for i in range(steps):
        batch = jax.tree.map(
            jnp.asarray, data.get_batch(dcfg, step=10_000_000 + i,
                                        table=table)
        )
        loss, (_, m) = transformer.loss_fn(
            params, model_state, batch, cfg, train=False
        )
        losses.append(float(loss))
    probe = jax.tree.map(jnp.asarray,
                         data.synthetic.fact_eval_batch(dcfg, n=64,
                                                        table=table))
    logits, _, _ = transformer.forward(params, model_state, probe, cfg)
    pred = jnp.argmax(logits, axis=-1)
    mask = probe["labels"] != data.synthetic.IGNORE
    recall = float((jnp.where(mask, pred == probe["labels"], False)).sum()
                   / mask.sum())
    return float(np.mean(losses)), recall


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="lram-bert-small")
    p.add_argument("--smoke", action="store_true",
                   help="use the reduced same-family config")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=1e-4)
    p.add_argument("--memory-lr-mult", type=float, default=10.0)
    p.add_argument("--compression", default="none",
                   choices=["none", "int8", "topk"])
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=100)
    p.add_argument("--eval-every", type=int, default=0)
    p.add_argument("--grow-at", default="",
                   help="memory-growth schedule STEP:NEW_LOG2[,STEP:...] — "
                        "grow the value table online at the given steps "
                        "(repro.memctl; e.g. '100:19,500:20')")
    p.add_argument("--simulate-failure-at", type=int, default=-1)
    p.add_argument("--telemetry", action="store_true",
                   help="carry in-graph memory-usage counters through the "
                        "train step and log utilisation_report rows "
                        "beside the loss (lram archs)")
    p.add_argument("--metrics-dir", default="",
                   help="arm the observability layer (repro.obs): spans "
                        "stream to <dir>/metrics.jsonl, a Prometheus "
                        "textfile snapshot lands at <dir>/metrics.prom")
    p.add_argument("--profile-dir", default="",
                   help="jax.profiler capture dir for marked spans "
                        "(needs --metrics-dir)")
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--use-mesh", action="store_true",
                   help="shard over all available devices")
    args = p.parse_args(argv)

    if args.metrics_dir:
        obs.configure(metrics_dir=args.metrics_dir,
                      profile_dir=args.profile_dir or None)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if args.telemetry and cfg.lram is None:
        raise SystemExit(f"--telemetry needs a memory arch; {cfg.name} "
                         f"has no LRAM layer")
    dcfg = data.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, objective=cfg.objective, seed=args.seed,
    )
    opt_cfg = optim.OptimConfig(lr=args.lr,
                                memory_lr_mult=args.memory_lr_mult)

    mesh = None
    if args.use_mesh and jax.device_count() > 1:
        mesh = mesh_lib.make_host_mesh()
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    key = jax.random.PRNGKey(args.seed)
    params, model_state = transformer.init(key, cfg)

    def bind_stores(params):
        # write-back-capable placements (tiered, sharded-tiered —
        # discovered via the resolved lookup plan) own their sparse
        # optimizer step (write-back SGD at the paper's memory LR); the
        # dense Adam below never sees their tables
        stores = (
            lookup.find_stores(params)
            if any(p.table_update == "writeback"
                   for p in lookup.model_plans(cfg))
            else []
        )
        for _, store in stores:
            store.writeback_lr = args.lr * args.memory_lr_mult
            store.warm()
        return stores

    stores = bind_stores(params)
    if mesh is not None:
        params = sharding.shard_params(params, mesh, model_cfg=cfg)
    opt_state = optim.adam_init(params)

    def init_residual(params):
        residual = optim.compression_init(params,
                                          args.compression)["residual"]
        if residual is None:
            residual = jnp.zeros(())  # jit-friendly placeholder
        return residual

    residual = init_residual(params)

    controller = None
    if args.grow_at:
        controller = memctl.MemoryController(memctl.LifecyclePolicy(
            grow_at=memctl.parse_grow_at(args.grow_at)
        ))
        if cfg.lram is None:
            raise SystemExit(f"--grow-at needs a memory arch; {cfg.name} "
                             f"has no LRAM layer")

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        latest = mgr.latest_step()
        if latest is not None:
            if controller is not None:
                # growths that fired before the checkpoint was taken must
                # be re-applied first, so the restore target (and its
                # grow-on-restore path) has the grown shape
                params, cfg, opt_state, changed = controller.catch_up(
                    latest, params, cfg, opt_state
                )
                if changed:
                    stores = bind_stores(params)
                    residual = init_residual(params)
            tree = {"params": params, "opt": opt_state,
                    "model_state": model_state}
            step_found, restored = mgr.restore(tree)
            if restored is not None:
                params = restored["params"]
                opt_state = restored["opt"]
                model_state = restored["model_state"]
                start_step = step_found
                print(f"resumed from step {start_step}")

    step_fn = build_train_step(cfg, opt_cfg, mesh, args.compression,
                               telemetry=args.telemetry)
    tel = init_telemetry(cfg) if args.telemetry else None
    monitor = fault.HeartbeatMonitor(num_hosts=jax.process_count())
    timer = fault.StepTimer()

    for step in range(start_step, args.steps):
        if controller is not None:
            params, cfg, opt_state, changed = controller.on_train_step(
                step, params, cfg, opt_state
            )
            if changed:
                # the grown table changes traced shapes (and, for stores,
                # capacity behind the same handles): re-bind write-back,
                # re-jit the step against the new config, and re-size the
                # compression residual (error feedback restarts at zero —
                # it mirrors params, including any grown dense table)
                stores = bind_stores(params)
                step_fn = build_train_step(cfg, opt_cfg, mesh,
                                           args.compression,
                                           telemetry=args.telemetry)
                residual = init_residual(params)
                if tel is not None:
                    # appended bins start dead; the utilisation log then
                    # shows the post-growth recovery curve directly
                    tel = {
                        name: memctl.grow_telemetry(
                            t, cfg.lram.num_locations
                        ) for name, t in tel.items()
                    }
                ev = controller.events[-1]
                print(json.dumps({
                    "grow": f"2^{ev['new_log2']}", "step": step,
                    "pause_s": ev["pause_s"],
                }))
        if step == args.simulate_failure_at:
            if mgr:
                mgr.wait()
            raise fault.SimulatedFailure(
                f"injected failure at step {step} (relaunch to resume)"
            )
        t0 = time.time()
        batch = jax.tree.map(jnp.asarray, data.get_batch(dcfg, step=step))
        with obs.span("train.step", step=step):
            if tel is None:
                params, opt_state, model_state, residual, metrics = step_fn(
                    params, opt_state, model_state, residual, batch
                )
            else:
                (params, opt_state, model_state, residual, metrics,
                 tel) = step_fn(
                    params, opt_state, model_state, residual, batch, tel
                )
        dt = time.time() - t0
        timer.record(dt)
        monitor.heartbeat(jax.process_index(), dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            slow = " STRAGGLER" if timer.is_outlier(dt) else ""
            rec = {
                "step": step,
                "loss": round(float(metrics["loss"]), 4),
                "xent": round(float(metrics["xent"]), 4),
                "grad_norm": round(float(metrics["grad_norm"]), 3),
                "sec": round(dt, 3),
            }
            if stores:
                rec["cache_hit"] = round(
                    float(np.mean([s.hit_rate() for _, s in stores])), 4
                )
            print(json.dumps(rec) + slow)
            if tel is not None:
                # hot/cold/dead utilisation beside the loss, one report
                # row set per lram segment (drained at the log boundary:
                # the counters themselves stay on device, in-graph)
                for name, t in tel.items():
                    rows = memctl.utilisation_report(
                        t, prefix=f"util_{name}"
                    )
                    print(json.dumps({"step": step,
                                      "utilisation_report": rows}))
                    s = memctl.utilisation_summary(t)
                    obs.gauge("train.util_dead_frac").set(s["dead_frac"])
                    obs.gauge("train.util_hot_mass").set(s["hot_mass"])
                    obs.gauge("train.util_cold_frac").set(s["cold_frac"])
        if mgr and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1,
                     {"params": params, "opt": opt_state,
                      "model_state": model_state},
                     blocking=False)
        if args.eval_every and (step + 1) % args.eval_every == 0:
            eval_loss, recall = evaluate(params, model_state, cfg, dcfg)
            print(json.dumps({"eval_loss": round(eval_loss, 4),
                              "fact_recall": round(recall, 4)}))

    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt_state,
                              "model_state": model_state})
        mgr.wait()
    eval_loss, recall = evaluate(params, model_state, cfg, dcfg)
    print(json.dumps({"final_eval_loss": round(eval_loss, 4),
                      "final_fact_recall": round(recall, 4)}))
    if args.metrics_dir:
        obs.flush()
    return params


if __name__ == "__main__":
    main()
