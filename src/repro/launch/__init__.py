"""End-to-end drivers (each runnable as `python -m repro.launch.<name>`).

Public surface:

  * `repro.launch.train`  — training loop: config -> init -> jitted step
    (grads [+ compression] + Adam with 10x memory LR, or tiered
    write-back) -> checkpoints/auto-resume -> straggler log
  * `repro.launch.serve`  — batched serving: prefill -> greedy decode with
    per-step latency, tiered-cache warmup/prefetch and hit-rate reporting
    (`--json` for a machine-readable summary)
  * `repro.launch.dryrun` — lower/compile/cost-analyze every arch x mode
    without running it (dispatch table for the smoke matrix)
  * `repro.launch.mesh`   — host-local mesh construction helpers
"""
