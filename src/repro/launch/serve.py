"""Batched serving driver: prefill a prompt batch, then decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
        --batch 4 --prompt-len 16 --gen 16

Demonstrates the production serve path the decode_* dry-run cells lower:
prefill -> KV caches -> repeated decode_step, with per-step latency stats
(and a straggler-step report from the same monitor the trainer uses).

Tiered memory (`lram-tiered` or any arch with `interp_impl="tiered"`): the
cache is warmed before prefill, each decode step's lattice accesses
prefetch the next step's shards (decode locality makes the previous step
the best predictor — the fill into the hot-cache mirror the jitted lookup
reads overlaps the next step's dense compute), and decode cache hit-rate
(prefill reported separately) rides the step monitor.

`--json` emits one machine-readable summary document: `rows` mirrors the
benchmark harness columns (name, us_per_call, derived — see benchmarks/run),
plus per-step decode latencies and the cache counters.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, memstore
from repro.distributed import fault
from repro.models import transformer


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi-9b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", action="store_true",
                   help="emit a machine-readable summary (benchmark-harness "
                        "row format + per-step latency + cache hit-rate)")
    args = p.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.objective != "clm":
        raise SystemExit("serving requires a causal-LM arch")

    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)
    params, state = transformer.init(key, cfg)
    stores = memstore.find_stores(params)
    for _, store in stores:  # cache warmup before the first prefill
        store.warm()
        store.reset_stats()
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size,
                     size=(args.batch, args.prompt_len)),
        dtype=jnp.int32,
    )
    max_len = args.prompt_len + args.gen

    t0 = time.time()
    batch = {"tokens": prompts}
    if cfg.family == "encdec":
        batch["encoder_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.encoder_len, cfg.d_model)
        ).astype(np.float32))
    logits, cache = transformer.prefill(params, state, batch, cfg, max_len)
    prefill_s = time.time() - t0
    # decode hit-rate must not be diluted by prefill's cold misses
    prefill_hit = (round(
        float(np.mean([s.hit_rate() for _, s in stores])), 4
    ) if stores else None)
    for _, store in stores:
        store.reset_stats()
    if not args.json:
        print(json.dumps({"prefill_sec": round(prefill_s, 3),
                          "tokens": args.batch * args.prompt_len}))

    step = jax.jit(
        lambda tok, pos, cache: transformer.decode_step(
            params, state, tok, pos, cache, cfg
        ),
    )
    timer = fault.StepTimer()
    step_ms: list[float] = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(args.gen - 1):
        t0 = time.time()
        logits_t, cache = step(tok, args.prompt_len + i, cache)
        tok = jnp.argmax(logits_t, axis=-1).astype(jnp.int32)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        timer.record(dt)
        step_ms.append(round(1e3 * dt, 3))
        out.append(tok)
        for _, store in stores:  # async fill overlaps the next step
            store.prefetch_last()
    gen = jnp.concatenate(out, axis=1)

    cache_stats = None
    if stores:
        cache_stats = {
            "hit_rate": round(
                float(np.mean([s.hit_rate() for _, s in stores])), 4
            ),
            "prefill_hit_rate": prefill_hit,
        }
        for k in ("hits", "misses", "uncached", "fills", "evictions"):
            cache_stats[k] = int(sum(s.stats[k] for _, s in stores))

    decode_us = 1e6 * timer.median()
    if args.json:
        rows = [
            ["serve_prefill", round(1e6 * prefill_s, 3),
             f"tokens={args.batch * args.prompt_len}"],
            ["serve_decode_step", round(decode_us, 3),
             f"hit={cache_stats['hit_rate']}" if cache_stats else "dense"],
        ]
        print(json.dumps({
            "arch": cfg.name,
            "rows": rows,
            "per_step_ms": step_ms,
            "decode_median_ms": round(1e3 * timer.median(), 2),
            "cache": cache_stats,
            "generated_shape": list(gen.shape),
        }))
    else:
        rec = {
            "decode_median_ms": round(1e3 * timer.median(), 2),
            "generated_shape": list(gen.shape),
            "sample": np.asarray(gen[0, :8]).tolist(),
        }
        if cache_stats:
            rec["cache_hit_rate"] = cache_stats["hit_rate"]
        print(json.dumps(rec))
    return gen


if __name__ == "__main__":
    main()
