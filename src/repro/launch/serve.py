"""Serving driver: a thin CLI over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch lram-tiered --smoke \
        --mode continuous --json

Builds a mixed-length request trace (`repro.serving.synthetic_trace`:
random prompt/generation lengths, optional Poisson arrivals via `--rate`)
and replays it through `repro.serving.ServeEngine`:

  * `--mode continuous` (default) — slot-based dynamic batching: sequences
    are admitted into and retired from a fixed pool of decode slots every
    step, with no recompilation (per-slot position vector, bucketed
    batch=1 prefill spliced into the slotted KV cache).
  * `--mode static` — the legacy fixed-batch loop for comparison: a batch
    is admitted only when every slot is free, so the longest sequence in a
    batch blocks the whole pool (head-of-line blocking).

Tiered memory (`lram-tiered` & friends): the cache is warmed before the
first prefill, each step's lattice accesses prefetch the next step's
shards for the union of in-flight sequences, and per-request decode cache
hit-rates ride the report.

Lifecycle (`repro.memctl`, docs/lifecycle.md): `--ckpt-dir` restores a
trained checkpoint, `--grow-to LOG2` pre-grows the table so checkpoints
taken after a `--grow-at` training run restore cleanly, `--placement`
overrides the lookup placement, and `--hbm-budget-mb` / `--spill-at-tick`
attach a MemoryController that migrates a dense table to the tiered store
live, between decode ticks, without dropping in-flight requests.

Per-tenant memory (`repro.serving.overlay`, docs/serving.md): `--tenants N`
assigns trace requests to a pool of N tenants and `--overlay-rows K` gives
each tenant a K-row copy-on-write overlay per lram layer over the shared
base table — attached at admission, written back every decode tick,
retired with the slot, zero recompilation.  `--overlay-ttl` /
`--overlay-budget-kb` add lifecycle enforcement through the controller,
and `--overlay-dir` persists overlays beside the checkpoint shards.

`--json` emits one machine-readable summary document whose `rows` mirror
the benchmark harness columns (name, us_per_call, derived — the schema
`benchmarks/run.py --json` shares; see `benchmarks.run.validate_summary`),
plus per-step latencies, p50/p99, tokens/sec, and per-request records.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import numpy as np

from repro import configs, memctl, obs
from repro.checkpoint import CheckpointManager
from repro.models import transformer
from repro.serving import EngineConfig, ServeEngine, synthetic_trace


def build_argparser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="lram-tiered")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--mode", choices=["continuous", "static"],
                   default="continuous")
    p.add_argument("--batch", type=int, default=4,
                   help="decode slots (continuous) / batch size (static)")
    p.add_argument("--prompt-len", type=int, default=16,
                   help="max prompt length in the trace")
    p.add_argument("--gen", type=int, default=16,
                   help="max generation budget per request")
    p.add_argument("--requests", type=int, default=None,
                   help="trace size (default: 2x --batch)")
    p.add_argument("--rate", type=float, default=0.0,
                   help="offered load in requests/sec (0 = all at t=0)")
    p.add_argument("--fixed-len", action="store_true",
                   help="pin every request to (--prompt-len, --gen) instead "
                        "of the mixed-length trace")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--placement", default="",
                   choices=["", "reference", "pallas", "tiered", "sharded",
                            "sharded-tiered"],
                   help="override the memory arch's lookup placement "
                        "(LRAMConfig.interp_impl) — e.g. serve lram-tiered "
                        "dense with --placement reference to demo the "
                        "HBM-budget spill")
    p.add_argument("--ckpt-dir", default="",
                   help="restore params from this checkpoint dir before "
                        "serving (e.g. one written by repro.launch.train)")
    p.add_argument("--grow-to", type=int, default=0, metavar="LOG2",
                   help="grow the memory table to 2^LOG2 locations before "
                        "restoring — serve a checkpoint taken after a "
                        "--grow-at training run")
    p.add_argument("--hbm-budget-mb", type=float, default=0.0,
                   help="spill a dense memory table to the tiered store "
                        "when its size exceeds this budget (live, between "
                        "decode ticks; repro.memctl)")
    p.add_argument("--spill-at-tick", type=int, default=-1,
                   help="deterministically spill dense->tiered at this "
                        "decode tick (demo/testing trigger)")
    p.add_argument("--tenants", type=int, default=0,
                   help="assign each trace request a tenant id from a pool "
                        "of this size (per-tenant memory overlays; 0 = "
                        "anonymous trace)")
    p.add_argument("--overlay-rows", type=int, default=0,
                   help="per-tenant overlay capacity in rows per lram "
                        "layer (0 = off; defaults to 8 when --tenants > 0)")
    p.add_argument("--overlay-write-lr", type=float, default=0.1,
                   help="decode-step Hebbian writeback rate into the "
                        "tenant overlay")
    p.add_argument("--overlay-ttl", type=int, default=0,
                   help="expire a detached tenant overlay after this many "
                        "idle decode ticks (0 = never)")
    p.add_argument("--overlay-budget-kb", type=float, default=0.0,
                   help="total overlay byte budget; LRU detached tenants "
                        "are offloaded beyond it (0 = unlimited)")
    p.add_argument("--overlay-dir", default="",
                   help="persist tenant overlays here (and spill/restore "
                        "through it); defaults to <--ckpt-dir>/overlays "
                        "when a checkpoint dir is given")
    p.add_argument("--metrics-dir", default="",
                   help="arm the observability layer (repro.obs): spans "
                        "stream to <dir>/metrics.jsonl, a Prometheus "
                        "textfile snapshot lands at <dir>/metrics.prom")
    p.add_argument("--profile-dir", default="",
                   help="jax.profiler capture dir for the serve.run span "
                        "(needs --metrics-dir)")
    p.add_argument("--json", action="store_true",
                   help="emit a machine-readable summary (benchmark-harness "
                        "row format + per-step latency + cache hit-rates)")
    return p


def main(argv=None):
    args = build_argparser().parse_args(argv)
    if args.metrics_dir:
        obs.configure(metrics_dir=args.metrics_dir,
                      profile_dir=args.profile_dir or None)
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))

    if args.placement:
        if cfg.lram is None:
            raise SystemExit(f"--placement needs a memory arch; {cfg.name} "
                             f"has no LRAM layer")
        cfg = dataclasses.replace(
            cfg, lram=dataclasses.replace(cfg.lram,
                                          interp_impl=args.placement)
        )

    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)
    params, state = transformer.init(key, cfg)
    if args.grow_to:
        params, cfg, _ = memctl.grow_model(params, cfg, 2**args.grow_to)
    if args.ckpt_dir:
        step, restored = CheckpointManager(args.ckpt_dir).restore(
            {"params": params, "model_state": state}
        )
        if restored is None:
            raise SystemExit(f"no restorable checkpoint in {args.ckpt_dir}")
        params, state = restored["params"], restored["model_state"]
        print(json.dumps({"restored_step": step}))

    overlay_rows = args.overlay_rows
    if overlay_rows == 0 and args.tenants > 0:
        overlay_rows = 8
    overlay_dir = args.overlay_dir
    if not overlay_dir and args.ckpt_dir and overlay_rows > 0:
        overlay_dir = os.path.join(args.ckpt_dir, "overlays")

    controller = None
    if (args.hbm_budget_mb > 0 or args.spill_at_tick >= 0
            or args.overlay_ttl > 0 or args.overlay_budget_kb > 0):
        controller = memctl.MemoryController(memctl.LifecyclePolicy(
            hbm_budget_bytes=(int(args.hbm_budget_mb * 2**20)
                              if args.hbm_budget_mb > 0 else None),
            spill_at_tick=(args.spill_at_tick
                           if args.spill_at_tick >= 0 else None),
            tenant_ttl_ticks=(args.overlay_ttl
                              if args.overlay_ttl > 0 else None),
            tenant_budget_bytes=(int(args.overlay_budget_kb * 1024)
                                 if args.overlay_budget_kb > 0 else None),
            overlay_spill_dir=overlay_dir or None,
        ))

    num_requests = (2 * args.batch if args.requests is None
                    else args.requests)
    trace = synthetic_trace(
        rng, num_requests,
        vocab_size=cfg.vocab_size,
        max_prompt=args.prompt_len,
        max_gen=args.gen,
        rate=args.rate,
        mixed=not args.fixed_len,
        tenants=args.tenants,
    )
    engine = ServeEngine(params, state, cfg, EngineConfig(
        slots=args.batch,
        max_len=args.prompt_len + args.gen,
        mode=args.mode,
        overlay_rows=overlay_rows,
        overlay_write_lr=args.overlay_write_lr,
    ), controller=controller)
    if engine.overlays is not None and overlay_dir:
        engine.overlays.spill_dir = overlay_dir
        restored_overlays = engine.overlays.load_all(overlay_dir)
        if restored_overlays:
            print(json.dumps({"restored_overlays": restored_overlays}))
    report = engine.run(trace)
    if engine.overlays is not None and overlay_dir:
        engine.overlays.save_all(overlay_dir)
    if controller is not None and controller.events:
        print(json.dumps({"lifecycle": controller.events}))
    if args.metrics_dir:
        obs.flush()

    if args.json:
        print(json.dumps(report.summary(cfg.name)))
    else:
        rec = {
            "mode": report.mode,
            "requests": len(report.requests),
            "generated_tokens": report.generated_tokens,
            "tokens_per_sec": round(report.tokens_per_sec, 2),
            "decode_p50_ms": round(report.p50_ms(), 3),
            "decode_p99_ms": round(report.p99_ms(), 3),
        }
        if report.cache:
            rec["cache_hit_rate"] = report.cache["hit_rate"]
        if report.overlay:
            rec["overlay"] = {k: report.overlay[k] for k in
                              ("tenants", "hit_rate", "bytes_per_tenant",
                               "writebacks")}
        print(json.dumps(rec))
    return report


if __name__ == "__main__":
    main()
