"""Serving driver: a thin CLI over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch lram-tiered --smoke \
        --mode continuous --json

Builds a mixed-length request trace (`repro.serving.synthetic_trace`:
random prompt/generation lengths, optional Poisson arrivals via `--rate`)
and replays it through `repro.serving.ServeEngine`:

  * `--mode continuous` (default) — slot-based dynamic batching: sequences
    are admitted into and retired from a fixed pool of decode slots every
    step, with no recompilation (per-slot position vector, bucketed
    batch=1 prefill spliced into the slotted KV cache).
  * `--mode static` — the legacy fixed-batch loop for comparison: a batch
    is admitted only when every slot is free, so the longest sequence in a
    batch blocks the whole pool (head-of-line blocking).

Tiered memory (`lram-tiered` & friends): the cache is warmed before the
first prefill, each step's lattice accesses prefetch the next step's
shards for the union of in-flight sequences, and per-request decode cache
hit-rates ride the report.

Lifecycle (`repro.memctl`, docs/lifecycle.md): `--ckpt-dir` restores a
trained checkpoint, `--grow-to LOG2` pre-grows the table so checkpoints
taken after a `--grow-at` training run restore cleanly, `--placement`
overrides the lookup placement, and `--hbm-budget-mb` / `--spill-at-tick`
attach a MemoryController that migrates a dense table to the tiered store
live, between decode ticks, without dropping in-flight requests.

`--json` emits one machine-readable summary document whose `rows` mirror
the benchmark harness columns (name, us_per_call, derived — the schema
`benchmarks/run.py --json` shares; see `benchmarks.run.validate_summary`),
plus per-step latencies, p50/p99, tokens/sec, and per-request records.
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro import configs, memctl
from repro.checkpoint import CheckpointManager
from repro.models import transformer
from repro.serving import EngineConfig, ServeEngine, synthetic_trace


def build_argparser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="lram-tiered")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--mode", choices=["continuous", "static"],
                   default="continuous")
    p.add_argument("--batch", type=int, default=4,
                   help="decode slots (continuous) / batch size (static)")
    p.add_argument("--prompt-len", type=int, default=16,
                   help="max prompt length in the trace")
    p.add_argument("--gen", type=int, default=16,
                   help="max generation budget per request")
    p.add_argument("--requests", type=int, default=None,
                   help="trace size (default: 2x --batch)")
    p.add_argument("--rate", type=float, default=0.0,
                   help="offered load in requests/sec (0 = all at t=0)")
    p.add_argument("--fixed-len", action="store_true",
                   help="pin every request to (--prompt-len, --gen) instead "
                        "of the mixed-length trace")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--placement", default="",
                   choices=["", "reference", "pallas", "tiered", "sharded",
                            "sharded-tiered"],
                   help="override the memory arch's lookup placement "
                        "(LRAMConfig.interp_impl) — e.g. serve lram-tiered "
                        "dense with --placement reference to demo the "
                        "HBM-budget spill")
    p.add_argument("--ckpt-dir", default="",
                   help="restore params from this checkpoint dir before "
                        "serving (e.g. one written by repro.launch.train)")
    p.add_argument("--grow-to", type=int, default=0, metavar="LOG2",
                   help="grow the memory table to 2^LOG2 locations before "
                        "restoring — serve a checkpoint taken after a "
                        "--grow-at training run")
    p.add_argument("--hbm-budget-mb", type=float, default=0.0,
                   help="spill a dense memory table to the tiered store "
                        "when its size exceeds this budget (live, between "
                        "decode ticks; repro.memctl)")
    p.add_argument("--spill-at-tick", type=int, default=-1,
                   help="deterministically spill dense->tiered at this "
                        "decode tick (demo/testing trigger)")
    p.add_argument("--json", action="store_true",
                   help="emit a machine-readable summary (benchmark-harness "
                        "row format + per-step latency + cache hit-rates)")
    return p


def main(argv=None):
    args = build_argparser().parse_args(argv)
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))

    if args.placement:
        if cfg.lram is None:
            raise SystemExit(f"--placement needs a memory arch; {cfg.name} "
                             f"has no LRAM layer")
        cfg = dataclasses.replace(
            cfg, lram=dataclasses.replace(cfg.lram,
                                          interp_impl=args.placement)
        )

    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)
    params, state = transformer.init(key, cfg)
    if args.grow_to:
        params, cfg, _ = memctl.grow_model(params, cfg, 2**args.grow_to)
    if args.ckpt_dir:
        step, restored = CheckpointManager(args.ckpt_dir).restore(
            {"params": params, "model_state": state}
        )
        if restored is None:
            raise SystemExit(f"no restorable checkpoint in {args.ckpt_dir}")
        params, state = restored["params"], restored["model_state"]
        print(json.dumps({"restored_step": step}))

    controller = None
    if args.hbm_budget_mb > 0 or args.spill_at_tick >= 0:
        controller = memctl.MemoryController(memctl.LifecyclePolicy(
            hbm_budget_bytes=(int(args.hbm_budget_mb * 2**20)
                              if args.hbm_budget_mb > 0 else None),
            spill_at_tick=(args.spill_at_tick
                           if args.spill_at_tick >= 0 else None),
        ))

    num_requests = (2 * args.batch if args.requests is None
                    else args.requests)
    trace = synthetic_trace(
        rng, num_requests,
        vocab_size=cfg.vocab_size,
        max_prompt=args.prompt_len,
        max_gen=args.gen,
        rate=args.rate,
        mixed=not args.fixed_len,
    )
    engine = ServeEngine(params, state, cfg, EngineConfig(
        slots=args.batch,
        max_len=args.prompt_len + args.gen,
        mode=args.mode,
    ), controller=controller)
    report = engine.run(trace)
    if controller is not None and controller.events:
        print(json.dumps({"lifecycle": controller.events}))

    if args.json:
        print(json.dumps(report.summary(cfg.name)))
    else:
        rec = {
            "mode": report.mode,
            "requests": len(report.requests),
            "generated_tokens": report.generated_tokens,
            "tokens_per_sec": round(report.tokens_per_sec, 2),
            "decode_p50_ms": round(report.p50_ms(), 3),
            "decode_p99_ms": round(report.p99_ms(), 3),
        }
        if report.cache:
            rec["cache_hit_rate"] = report.cache["hit_rate"]
        print(json.dumps(rec))
    return report


if __name__ == "__main__":
    main()
