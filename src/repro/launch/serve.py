"""Batched serving driver: prefill a prompt batch, then decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
        --batch 4 --prompt-len 16 --gen 16

Demonstrates the production serve path the decode_* dry-run cells lower:
prefill -> KV caches -> repeated decode_step, with per-step latency stats
(and a straggler-step report from the same monitor the trainer uses).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed import fault
from repro.models import transformer


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi-9b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.objective != "clm":
        raise SystemExit("serving requires a causal-LM arch")

    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)
    params, state = transformer.init(key, cfg)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size,
                     size=(args.batch, args.prompt_len)),
        dtype=jnp.int32,
    )
    max_len = args.prompt_len + args.gen

    t0 = time.time()
    batch = {"tokens": prompts}
    if cfg.family == "encdec":
        batch["encoder_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.encoder_len, cfg.d_model)
        ).astype(np.float32))
    logits, cache = transformer.prefill(params, state, batch, cfg, max_len)
    prefill_s = time.time() - t0
    print(json.dumps({"prefill_sec": round(prefill_s, 3),
                      "tokens": args.batch * args.prompt_len}))

    step = jax.jit(
        lambda tok, pos, cache: transformer.decode_step(
            params, state, tok, pos, cache, cfg
        ),
    )
    timer = fault.StepTimer()
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(args.gen - 1):
        t0 = time.time()
        logits_t, cache = step(tok, args.prompt_len + i, cache)
        tok = jnp.argmax(logits_t, axis=-1).astype(jnp.int32)
        jax.block_until_ready(tok)
        timer.record(time.time() - t0)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    print(json.dumps({
        "decode_median_ms": round(1e3 * timer.median(), 2),
        "generated_shape": list(gen.shape),
        "sample": np.asarray(gen[0, :8]).tolist(),
    }))
    return gen


if __name__ == "__main__":
    main()
