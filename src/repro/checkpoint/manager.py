"""Fault-tolerant checkpointing: atomic, checksummed, async, elastic.

Layout:  <dir>/step_<n>/
            manifest.json   — leaf paths, shapes, dtypes, crc32 checksums
            <leaf>.npy      — one file per tree leaf (path-mangled)

Guarantees:
  * atomicity   — writes go to `step_<n>.tmp/` and are renamed only after
    the manifest (written last) is fsync'd; a crash mid-save never corrupts
    the latest valid checkpoint;
  * integrity   — restore verifies every leaf's crc32 against the manifest
    and falls back to the newest *valid* checkpoint;
  * async       — `save(..., blocking=False)` snapshots to host memory
    synchronously (cheap) and writes in a daemon thread, overlapping I/O
    with the next training steps;
  * elasticity  — `restore(sharding=...)` re-places leaves under any target
    NamedSharding, so a checkpoint taken on one mesh resumes on another
    (mesh-reshape restart).  At fleet scale each host would read only its
    shard slices; here leaves are small enough to round-trip via host numpy.
  * retention   — keep the newest `keep` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _mangle(path: str) -> str:
    return path.replace("/", "__") + ".npy"


def _tree_items(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        items.append((name, leaf))
    return items


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ---------------- save ----------------

    def save(self, step: int, tree, *, blocking: bool = True) -> None:
        # snapshot to host memory synchronously (device buffers may mutate)
        host = [(name, np.asarray(jax.device_get(leaf)))
                for name, leaf in _tree_items(tree)]
        self.wait()  # one writer at a time (async or blocking)
        if blocking:
            self._write(step, host)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_items) -> None:
        final = os.path.join(self.dir, f"step_{step:012d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for name, arr in host_items:
            fn = _mangle(name)
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][name] = {
                "file": fn,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:012d}"), ignore_errors=True
            )

    # ---------------- restore ----------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.removeprefix("step_")))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_dir(self, step: int):
        d = os.path.join(self.dir, f"step_{step:012d}")
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
        out = {}
        for name, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(d, meta["file"]))
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checksum mismatch for {name} at step {step}")
            out[name] = arr
        return out

    def restore(self, like, *, step: int | None = None, sharding=None):
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs).  Tries newest-first until a valid checkpoint
        loads; `sharding` is a pytree (or single sharding) for elastic
        re-placement on a different mesh.

        Returns (step, tree) or (None, None) if nothing restorable."""
        steps = [step] if step is not None else self.all_steps()[::-1]
        data = None
        found = None
        for s in steps:
            try:
                data = self._load_dir(s)
                found = s
                break
            except Exception:
                continue
        if data is None:
            return None, None

        names = [name for name, _ in _tree_items(like)]
        missing = [n for n in names if n not in data]
        if missing:
            raise KeyError(f"checkpoint at step {found} missing: {missing[:5]}")

        flat_like, treedef = jax.tree_util.tree_flatten(like)
        shard_flat = (
            jax.tree_util.tree_flatten(sharding)[0]
            if sharding is not None and not _is_single_sharding(sharding)
            else [sharding] * len(flat_like)
        )
        leaves = []
        for name, proto, shd in zip(names, flat_like, shard_flat):
            arr = data[name]
            want = getattr(proto, "dtype", None)
            if want is not None and str(arr.dtype) != str(want):
                arr = arr.astype(want)
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return found, jax.tree_util.tree_unflatten(treedef, leaves)


def _is_single_sharding(s) -> bool:
    return hasattr(s, "addressable_devices") or hasattr(s, "device_set")
