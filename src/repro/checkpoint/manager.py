"""Fault-tolerant checkpointing: atomic, checksummed, async, elastic.

Layout:  <dir>/step_<n>/
            manifest.json   — leaf paths, shapes, dtypes, crc32 checksums
            <leaf>.npy      — one file per tree leaf (path-mangled)

Guarantees:
  * atomicity   — writes go to `step_<n>.tmp/` and are renamed only after
    the manifest (written last) is fsync'd; a crash mid-save never corrupts
    the latest valid checkpoint;
  * integrity   — restore verifies every leaf's crc32 against the manifest
    and falls back to the newest *valid* checkpoint;
  * async       — `save(..., blocking=False)` snapshots to host memory
    synchronously (cheap) and writes in a daemon thread, overlapping I/O
    with the next training steps;
  * elasticity  — `restore(sharding=...)` re-places leaves under any target
    NamedSharding, so a checkpoint taken on one mesh resumes on another
    (mesh-reshape restart).  At fleet scale each host would read only its
    shard slices; here leaves are small enough to round-trip via host numpy.
  * retention   — keep the newest `keep` checkpoints.
  * tiered      — `repro.memstore.TieredValueStore` leaves are saved by
    *streaming* host shards to `<leaf>.shards/shard_NNNNNN.npy` one at a
    time (dirty cache slots flushed first), so a host-offloaded table
    checkpoints without ever being materialized on device — or even as a
    second host copy.  Restore streams shards back into the live store
    in place.  A store referenced from several tree positions (params +
    Adam moments share the node) is written once and cross-referenced.
    Saves containing tiered stores are forced blocking: the store keeps
    training-mutable state, so the async snapshot trick does not apply.
  * quantized   — a quantized store (`TieredSpec.quant` of int8/fp8)
    checkpoints its 1-byte payload plus `scale_NNNNNN.npy` per-row fp32
    scales, each independently checksummed.  Restore converts freely:
    quantized shards stream into a dense store (dequantized) or a dense
    checkpoint into a quantized store (requantized, nearest) — see
    `TieredValueStore.load_shard`.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np

from repro import quant
from repro.core import lookup
from repro.memstore import TieredValueStore

_MANIFEST = "manifest.json"


def _mangle(path: str) -> str:
    return path.replace("/", "__") + ".npy"


def _is_store(x) -> bool:
    # every registered offloaded-store class (TieredValueStore,
    # ShardedTieredStore, ...) exposes the same shard-streaming interface:
    # num_shards/shard_rows/m, flush, shard_host, shard_scale_host,
    # load_shard, load_dense.  The on-disk stream uses *global* shard ids,
    # so tiered <-> sharded-tiered checkpoints restore into each other.
    return lookup.is_store(x)


def _tree_items(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=_is_store)
    items = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        items.append((name, leaf))
    return items


class CheckpointError(ValueError):
    """A checkpoint/target size mismatch the manager cannot reconcile —
    e.g. restoring a larger table into a smaller one (shrink), or
    incompatible shard geometry.  A *caller* error: raised through the
    newest-first fallback instead of silently trying older checkpoints.

    The reconcilable direction — a smaller checkpoint into a larger
    table — restores via grow-on-restore: old shards stream in at their
    ids, appended rows warm-start from their coarse-lattice parent
    (`j mod old_N`, the inverse of `repro.memctl.grow`'s append rule)."""


class _StructureMismatch(KeyError):
    """`like` asks for leaves the checkpoint does not have — a caller
    error, re-raised instead of triggering newest-first fallback."""


class _TieredLeaf:
    """A verified, not-yet-loaded tiered table inside a checkpoint dir."""

    def __init__(self, directory: str, meta: dict):
        self.dir = directory
        self.meta = meta

    def shard_path(self, i: int) -> str:
        return os.path.join(self.dir, self.meta["dir"], f"shard_{i:06d}.npy")

    def scale_path(self, i: int) -> str:
        return os.path.join(self.dir, self.meta["dir"], f"scale_{i:06d}.npy")

    @property
    def quant(self) -> str:
        return self.meta.get("quant", "none")

    def _read_shard(self, i: int) -> np.ndarray:
        """Load + checksum one shard — verify-while-loading, single read."""
        arr = np.load(self.shard_path(i))
        if zlib.crc32(np.ascontiguousarray(arr).tobytes()) \
                != self.meta["crc32"][i]:
            raise IOError(f"checksum mismatch for shard {i}")
        return arr

    def _read_scale(self, i: int) -> np.ndarray:
        arr = np.load(self.scale_path(i))
        if zlib.crc32(np.ascontiguousarray(arr).tobytes()) \
                != self.meta["scale_crc32"][i]:
            raise IOError(f"checksum mismatch for shard {i} scales")
        return arr

    def load_into(self, store: TieredValueStore,
                  mutated: list | None = None) -> TieredValueStore:
        meta = self.meta
        if meta["shard_rows"] != store.shard_rows or meta["m"] != store.m:
            raise CheckpointError(
                f"tiered shard geometry mismatch: checkpoint has "
                f"{meta['num_shards']}x{meta['shard_rows']}x{meta['m']}, "
                f"store is {store.num_shards}x{store.shard_rows}x{store.m}"
            )
        if meta["num_shards"] > store.num_shards:
            raise CheckpointError(
                f"cannot shrink: checkpoint has {meta['num_shards']} "
                f"shards, store only {store.num_shards} — restore into a "
                f"table of at least the checkpoint's size (or grow the "
                f"store with repro.memctl first)"
            )
        if store.num_shards % meta["num_shards"]:
            raise CheckpointError(
                f"grow-on-restore needs the store's {store.num_shards} "
                f"shards to be a multiple of the checkpoint's "
                f"{meta['num_shards']}"
            )
        for i in range(meta["num_shards"]):
            # may raise: mark mutation first.  load_shard converts between
            # quantized and dense payloads as needed, so any (checkpoint
            # quant) x (store quant) pairing restores shard by shard.
            arr = self._read_shard(i)
            scale = self._read_scale(i) if self.quant != "none" else None
            if mutated is not None and store not in mutated:
                mutated.append(store)
            store.load_shard(i, arr, scale)
            # grow-on-restore: appended shards alias their coarse-lattice
            # parent shard (memctl.grow's append rule is j mod old_N, and
            # shard_rows divides old_N, so parents align shard-for-shard)
            for j in range(i + meta["num_shards"], store.num_shards,
                           meta["num_shards"]):
                store.load_shard(j, arr, scale)
        return store

    def materialize(self) -> np.ndarray:
        """Concatenate shards into a dense host table (restore-into-dense);
        quantized checkpoints are dequantized to fp32 on the way out."""
        meta = self.meta
        quantized = self.quant != "none"
        out = np.empty(
            (meta["num_shards"] * meta["shard_rows"], meta["m"]),
            np.float32 if quantized else np.dtype(meta["dtype"]),
        )
        r = meta["shard_rows"]
        for i in range(meta["num_shards"]):
            arr = self._read_shard(i)
            if quantized:
                arr = quant.dequantize_rows_np(arr, self._read_scale(i))
            out[i * r:(i + 1) * r] = arr
        return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ---------------- save ----------------

    def save(self, step: int, tree, *, blocking: bool = True) -> None:
        # snapshot to host memory synchronously (device buffers may mutate)
        host, stores = [], []
        for name, leaf in _tree_items(tree):
            if _is_store(leaf):
                stores.append((name, leaf))
            else:
                host.append((name, np.asarray(jax.device_get(leaf))))
        self.wait()  # one writer at a time (async or blocking)
        if blocking or stores:  # shard streaming reads live store state
            self._write(step, host, stores)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, stores), daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_items, store_items=()) -> None:
        final = os.path.join(self.dir, f"step_{step:012d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for name, arr in host_items:
            fn = _mangle(name)
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][name] = {
                "file": fn,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        seen: dict[int, str] = {}
        for name, store in store_items:
            if id(store) in seen:  # params + optimizer share the node
                manifest["leaves"][name] = {
                    "kind": "tiered_ref", "ref": seen[id(store)]
                }
                continue
            seen[id(store)] = name
            store.flush()
            sub = _mangle(name) + ".shards"
            os.makedirs(os.path.join(tmp, sub))
            quantized = store.quant != "none"
            crcs, scale_crcs = [], []
            for i in range(store.num_shards):  # streamed, one shard at a time
                arr = store.shard_host(i)
                np.save(os.path.join(tmp, sub, f"shard_{i:06d}.npy"), arr)
                crcs.append(zlib.crc32(np.ascontiguousarray(arr).tobytes()))
                if quantized:  # per-row fp32 scales ride beside the payload
                    s = store.shard_scale_host(i)
                    np.save(os.path.join(tmp, sub, f"scale_{i:06d}.npy"), s)
                    scale_crcs.append(
                        zlib.crc32(np.ascontiguousarray(s).tobytes())
                    )
            manifest["leaves"][name] = {
                "kind": "tiered",
                "dir": sub,
                "num_shards": store.num_shards,
                "shard_rows": store.shard_rows,
                "m": store.m,
                "dtype": str(store.dtype),
                "crc32": crcs,
            }
            if quantized:
                manifest["leaves"][name]["quant"] = store.quant
                manifest["leaves"][name]["scale_crc32"] = scale_crcs
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:012d}"), ignore_errors=True
            )

    # ---------------- restore ----------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.removeprefix("step_")))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_dir(self, step: int):
        d = os.path.join(self.dir, f"step_{step:012d}")
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
        out = {}
        refs = {}
        for name, meta in manifest["leaves"].items():
            kind = meta.get("kind", "array")
            if kind == "tiered":
                # shards are checksummed while streaming into the target in
                # restore() — a corrupt shard raises there, inside the same
                # newest-first fallback loop (no second read of the table)
                out[name] = _TieredLeaf(d, meta)
            elif kind == "tiered_ref":
                refs[name] = meta["ref"]
            else:
                arr = np.load(os.path.join(d, meta["file"]))
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc32"]:
                    raise IOError(
                        f"checksum mismatch for {name} at step {step}"
                    )
                out[name] = arr
        for name, target in refs.items():
            out[name] = out[target]
        return out

    def restore(self, like, *, step: int | None = None, sharding=None):
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs).  Tries newest-first until a valid checkpoint
        loads; `sharding` is a pytree (or single sharding) for elastic
        re-placement on a different mesh.

        Returns (step, tree) or (None, None) if nothing restorable.

        Tiered shards are checksummed *while* streaming into the target
        store (single read); a corrupt shard aborts that attempt and falls
        back to the next-newest checkpoint, whose load overwrites every
        shard again.  If every candidate fails AFTER a live store was
        partially overwritten, restore raises instead of returning
        (None, None) — silently training on a half-loaded table is worse
        than stopping.
        """
        steps = [step] if step is not None else self.all_steps()[::-1]
        mutated: list = []
        for s in steps:
            try:
                data = self._load_dir(s)
                return s, self._assemble(like, data, s, sharding, mutated)
            except (_StructureMismatch, CheckpointError):
                raise  # `like` does not match the checkpoint: caller error
            except Exception:
                continue
        if mutated:
            raise IOError(
                "no valid checkpoint found, and a tiered value store was "
                "partially overwritten during failed restore attempts — "
                "re-initialize it before training"
            )
        return None, None

    def _assemble(self, like, data, found, sharding, mutated=None):
        names = [name for name, _ in _tree_items(like)]
        missing = [n for n in names if n not in data]
        if missing:
            raise _StructureMismatch(
                f"checkpoint at step {found} missing: {missing[:5]}"
            )

        flat_like, treedef = jax.tree_util.tree_flatten(like, is_leaf=_is_store)
        shard_flat = (
            jax.tree_util.tree_flatten(sharding, is_leaf=_is_store)[0]
            if sharding is not None and not _is_single_sharding(sharding)
            else [sharding] * len(flat_like)
        )
        leaves = []
        loaded_stores: set[int] = set()
        for name, proto, shd in zip(names, flat_like, shard_flat):
            arr = data[name]
            if _is_store(proto):
                if id(proto) not in loaded_stores:
                    loaded_stores.add(id(proto))
                    if isinstance(arr, _TieredLeaf):
                        arr.load_into(proto, mutated)  # streamed, in place
                    else:  # dense checkpoint -> tiered store
                        if mutated is not None and proto not in mutated:
                            mutated.append(proto)
                        # the proto IS a registered store: a memory table
                        # regardless of its tree path
                        proto.load_dense(_reconcile_rows(
                            name, np.asarray(arr),
                            (proto.num_rows, proto.m), is_table=True,
                        ))
                leaves.append(proto)
                continue
            if isinstance(arr, _TieredLeaf):  # tiered checkpoint -> dense
                arr = arr.materialize()
            shape = getattr(proto, "shape", None)
            if shape is not None and tuple(arr.shape) != tuple(shape):
                arr = _reconcile_rows(name, np.asarray(arr), tuple(shape))
            want = getattr(proto, "dtype", None)
            if want is not None and str(arr.dtype) != str(want):
                arr = arr.astype(want)
            if shd is not None and not _is_store(shd):
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)


def _is_lram_table_path(name: str) -> bool:
    """Does this leaf path name an LRAM value table?  Matches
    `…/lram/values` (and a QuantizedTable's `…/lram/values/<child>`) plus
    the bare `values` of a layer-level param dict — NOT `pkm/values` or
    other coincidental `values` leaves, whose rows carry no
    lattice-parent structure to alias-grow by."""
    parts = name.split("/")
    if parts and parts[-1].isdigit():
        parts = parts[:-1]
    if parts[-1:] != ["values"]:
        return False
    return len(parts) == 1 or parts[-2] == "lram"


def _reconcile_rows(name: str, arr: np.ndarray, want: tuple, *,
                    is_table: bool | None = None) -> np.ndarray:
    """Reconcile a checkpoint leaf against a differently-sized target.

    Memory-table leaves (the fp32 table, a quantized payload, or its
    per-row scales — all row-major over N) grow-on-restore by the alias
    rule `j mod old_N` (tiling), matching `repro.memctl.grow`'s append: a
    smaller checkpoint warm-starts a larger table.  Everything else —
    shrinks, non-multiple sizes, non-table leaves — raises a clear
    `CheckpointError` instead of handing back a silently mis-shaped leaf.
    """
    if tuple(arr.shape) == tuple(want):
        return arr
    if is_table is None:
        is_table = _is_lram_table_path(name)
    rows_compatible = (
        is_table
        and len(want) == arr.ndim
        and tuple(arr.shape[1:]) == tuple(want[1:])
    )
    if rows_compatible and want[0] > arr.shape[0] \
            and want[0] % arr.shape[0] == 0:
        reps = (want[0] // arr.shape[0],) + (1,) * (arr.ndim - 1)
        return np.tile(arr, reps)
    if rows_compatible and want[0] < arr.shape[0]:
        raise CheckpointError(
            f"cannot shrink {name}: checkpoint has {arr.shape[0]} rows, "
            f"target {want[0]} — restore into a table of at least the "
            f"checkpoint's size"
        )
    raise CheckpointError(
        f"shape mismatch for {name}: checkpoint {tuple(arr.shape)} vs "
        f"target {tuple(want)}"
    )


def _is_single_sharding(s) -> bool:
    return hasattr(s, "addressable_devices") or hasattr(s, "device_set")
