"""Fault-tolerant checkpointing.

Public surface: `CheckpointManager` — atomic (tmp-dir + rename),
checksummed (per-leaf / per-shard crc32), async for dense trees,
shard-streaming for tiered value stores (quantized payload + scales when
`TieredSpec.quant` is set), with newest-valid-first restore and elastic
re-sharding.
"""

from repro.checkpoint.manager import CheckpointManager  # noqa: F401
