"""Fault-tolerant checkpointing.

Public surface: `CheckpointManager` — atomic (tmp-dir + rename),
checksummed (per-leaf / per-shard crc32), async for dense trees,
shard-streaming for tiered value stores (quantized payload + scales when
`TieredSpec.quant` is set), with newest-valid-first restore, elastic
re-sharding, and grow-on-restore for memory tables (a smaller checkpoint
warm-starts a larger table via the `repro.memctl` alias rule) — size
mismatches the manager cannot reconcile raise `CheckpointError`.
"""

from repro.checkpoint.manager import (  # noqa: F401
    CheckpointError,
    CheckpointManager,
)
