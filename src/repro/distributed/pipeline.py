"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

Each device along the `pipe` axis owns one stage's params.  Microbatches
march through the ring: at every tick each stage computes on the activation
it holds and collective-permutes it to the next stage.  With M microbatches
and S stages the schedule runs S + M - 1 ticks (classic GPipe bubble
(S-1)/(S+M-1)); activations for in-flight microbatches live in a rolling
buffer.  Used to host pipeline stages on the `pod` axis (DCN-friendly:
point-to-point permutes only, no all-to-alls across pods).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from repro.distributed._compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(
    stage_fn: Callable,
    stacked_params,
    x: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "pod",
    num_microbatches: int,
):
    """Run x through `n_stages` sequential applications of stage_fn.

    stage_fn(params_i, x) -> x, applied in stage order along `axis`.
    stacked_params: leading dim == mesh.shape[axis] (one slice per stage).
    x: (batch, ...) with batch % num_microbatches == 0.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % num_microbatches == 0
    mb = b // num_microbatches

    def per_stage(params_l, x_l):
        # params_l: one stage's params (leading stage dim stripped by specs)
        params_l = jax.tree.map(lambda a: a[0], params_l)
        stage = jax.lax.axis_index(axis)
        n_ticks = n_stages + num_microbatches - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        micro = x_l.reshape(num_microbatches, mb, *x_l.shape[1:])
        outputs = jnp.zeros_like(micro)
        carry = jnp.zeros((mb,) + x_l.shape[1:], x_l.dtype)

        def tick(t, state):
            carry, outputs = state
            # stage 0 ingests microbatch t (if any remain)
            feed = micro[jnp.clip(t, 0, num_microbatches - 1)]
            inp = jnp.where(stage == 0, feed, carry)
            out = stage_fn(params_l, inp)
            # last stage retires microbatch t - (n_stages - 1)
            done_idx = t - (n_stages - 1)
            write = jnp.logical_and(stage == n_stages - 1, done_idx >= 0)
            outputs = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_slice_in_dim(
                    o, out[None], jnp.maximum(done_idx, 0), axis=0
                ),
                lambda o: o,
                outputs,
            )
            carry = jax.lax.ppermute(out, axis, perm)
            return carry, outputs

        _, outputs = jax.lax.fori_loop(
            0, n_ticks, tick, (carry, outputs)
        )
        # results live on the last stage; share them back to every stage so
        # the caller sees a replicated output (one more ring rotation)
        outputs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs, 0.0), axis
        )
        return outputs.reshape(b, *x_l.shape[1:])

    pspecs = jax.tree.map(lambda _: P(axis), stacked_params)
    return shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(pspecs, P()),
        out_specs=P(),
        check_vma=False,
    )(stacked_params, x)
