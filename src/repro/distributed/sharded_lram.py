"""Model-parallel LRAM lookups: the `sharded` and `sharded-tiered`
placements of the lookup-plan registry (`repro.core.lookup`).

THE key TPU-native re-think of the paper's random-access memory (DESIGN.md
§3): the value table's rows are sharded over the `model` mesh axis.  Instead
of cross-chip random access (ruinous on TPU interconnects), every device

  1. receives the full (replicated-over-model) index/weight sets,
  2. gathers ONLY indices that fall inside its row shard (others masked to
     weight zero, index clamped),
  3. partially interpolates, and
  4. joins the partial outputs with a single psum over `model`.

Communication is O(tokens * heads * m) — *independent of N* — identical in
shape to a tensor-parallel FFN's reduce.  The O(1)-in-N property of the
paper survives sharding.  The backward pass (autodiff through shard_map)
scatter-adds only into local rows: value-table gradients never cross the
model axis at all.

Composition over the plan axes:

* **storage** — a `repro.quant.QuantizedTable` shards payload + per-row
  scales over the same axis; each device dequantizes only the rows it
  gathers locally, and the psum'd fp32 partials are unchanged —
  quantization is invisible to the collective.
* **kernel** — the shard-local gather can run the Pallas scalar-prefetch
  kernel (`kernel="pallas"`; `repro.kernels.gather_interp`) instead of
  jnp take+einsum.  The custom-VJP wrappers keep the sparse backward
  contract inside `shard_map`.
* **tiering** — :class:`ShardedTieredStore` composes row-sharding with
  the host-offloaded tiered store: each model shard owns a contiguous
  row *range* backed by its own `TieredValueStore` (host shards + device
  hot cache), so the aggregate table can exceed any single host's
  memory.  Lookups route each index to its owning range, the ranges
  produce masked partial interpolations, and the partials are summed —
  the same partial-sum join as the dense sharded path (the psum, when
  ranges live on separate hosts).
"""

from __future__ import annotations

import dataclasses
import os
from concurrent.futures import ThreadPoolExecutor
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.core import lookup
from repro.distributed import context as _ctx
from repro.distributed._compat import shard_map
from repro.memstore.store import TieredSpec, TieredValueStore
from repro.quant import QuantizedTable, dequantize_rows

AXIS = "model"


def sharded_gather_interp(mesh: Mesh, *, axis: str = AXIS,
                          kernel: str = "reference",
                          interpret: bool | None = None):
    """Returns an `interp_impl` hook (values, idx, w) -> out for lram_apply.

    values must be laid out P(axis, None); idx/w replicated along `axis`
    (they are functions of activations, which are batch-sharded on `data`).
    `values` may also be a `repro.quant.QuantizedTable`: its payload and
    per-row scales shard over the same axis, each device dequantizes only
    the rows it gathers locally, and the psum'd partials are unchanged —
    quantization is invisible to the collective.

    `kernel` selects the shard-local gather: "reference" (jnp) or
    "pallas" (`repro.kernels.gather_interp`, differentiable wrappers).
    """
    if kernel not in ("reference", "pallas"):
        raise ValueError(f"unknown kernel {kernel!r}")
    n_shards = mesh.shape[axis]
    other = tuple(a for a in mesh.axis_names if a != axis)
    act_spec = P(other if len(other) > 1 else (other[0] if other else None))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    def interp(values, idx, w):
        quantized = isinstance(values, QuantizedTable)
        table = values.q if quantized else values
        rows_local = table.shape[0] // n_shards

        def local_rows(values_l, idx_l):
            base = jax.lax.axis_index(axis) * rows_local
            rel = idx_l - base
            ok = (rel >= 0) & (rel < rows_local)
            rel_safe = jnp.clip(rel, 0, rows_local - 1)
            return rel_safe, ok

        def local(values_l, idx_l, w_l):
            rel_safe, ok = local_rows(values_l, idx_l)
            wm = w_l * ok.astype(w_l.dtype)
            if kernel == "pallas":
                from repro.kernels import gather_interp as gi

                out = gi.gather_interp_vjp(values_l, rel_safe, wm, interpret)
            else:
                rows = jnp.take(values_l, rel_safe, axis=0).astype(w_l.dtype)
                out = jnp.einsum("...k,...km->...m", wm, rows)
            return jax.lax.psum(out, axis)

        def local_quant(values_l, scale_l, idx_l, w_l):
            rel_safe, ok = local_rows(values_l, idx_l)
            wm = w_l * ok.astype(w_l.dtype)
            if kernel == "pallas":
                from repro.kernels import gather_interp as gi

                out = gi.gather_interp_quant(
                    values_l, scale_l, rel_safe, wm, interpret
                )
            else:
                rows = dequantize_rows(  # in-shard dequant, fp32 partials
                    jnp.take(values_l, rel_safe, axis=0),
                    jnp.take(scale_l, rel_safe, axis=0),
                ).astype(w_l.dtype)
                out = jnp.einsum("...k,...km->...m", wm, rows)
            return jax.lax.psum(out, axis)

        dim_spec = act_spec[0] if len(act_spec) else None
        io_spec = P(*((dim_spec,) + (None,) * (idx.ndim - 1)))
        if quantized:
            return shard_map(
                local_quant,
                mesh=mesh,
                in_specs=(P(axis, None), P(axis), io_spec, io_spec),
                out_specs=io_spec,
                check_vma=False,
            )(values.q, values.scale, idx, w)
        return shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axis, None), io_spec, io_spec),
            out_specs=io_spec,
            check_vma=False,
        )(values, idx, w)

    return interp


# ---------------------------------------------------------------------------
# sharded × tiered: per-model-shard host-offloaded row ranges
# ---------------------------------------------------------------------------

class ShardedTieredStore:
    """A row-range-sharded tiered table: `num_ranges` host-offloaded
    `TieredValueStore`s, each owning `num_rows / num_ranges` consecutive
    rows with its own device hot cache.

    This is the composition the old callable-hook protocol could not
    express: the *capacity* axis of tiering (table larger than HBM — and,
    across ranges, larger than any single host) under the *ownership*
    layout of model sharding (each shard's write-back, checkpoint
    streaming, and fills touch only its local range).  Lookups route each
    (index, weight) element to its owning range; every range contributes a
    masked partial interpolation and the partials are summed — exactly the
    psum join of the dense sharded path when ranges live on separate
    hosts (here they share one process, so the sum is local).

    Presents the same surface as `TieredValueStore` everywhere the rest
    of the repo cares: `gather` / `gather_rows_host` / `apply_writeback`
    for the lookup (so `repro.memstore.tiered_interp` drives it
    unchanged, eager and traced), `prefetch* / warm / flush / stats` for
    the serve engine and trainer, and the shard-streaming checkpoint
    interface with *global* shard ids (`shard_host(i)` etc.), which makes
    a sharded-tiered checkpoint byte-compatible with a plain tiered one
    of the same total layout — restore converts freely between the two.
    """

    def __init__(self, num_rows: int, m: int, spec: TieredSpec,
                 num_ranges: int, *, dtype=np.float32):
        if num_ranges < 1:
            raise ValueError("need at least one row range")
        if num_rows % num_ranges:
            raise ValueError(
                f"num_rows={num_rows} not divisible by "
                f"num_ranges={num_ranges}"
            )
        rows_local = num_rows // num_ranges
        if rows_local % spec.shard_rows:
            raise ValueError(
                f"range size {rows_local} not divisible by "
                f"shard_rows={spec.shard_rows}"
            )
        self.spec = spec
        self.num_rows = num_rows
        self.m = m
        self.num_ranges = num_ranges
        self.rows_local = rows_local
        self.quant = spec.quant
        self.shard_rows = spec.shard_rows
        self.dtype = np.dtype(dtype)
        self.parts = [
            TieredValueStore(rows_local, m, self._part_spec(spec, r),
                             dtype=dtype)
            for r in range(num_ranges)
        ]
        self._shards_per_range = self.parts[0].num_shards
        self.num_shards = num_ranges * self._shards_per_range
        self._traced_interp = None  # built lazily by repro.memstore.interp
        self._pool: ThreadPoolExecutor | None = None  # prefetch executor

    @staticmethod
    def _part_spec(spec: TieredSpec, r: int) -> TieredSpec:
        # mmap backings need one directory per range (the store's file
        # name encodes only rows x m, identical across ranges)
        if spec.backing == "mmap" and spec.backing_dir is not None:
            return dataclasses.replace(
                spec, backing_dir=os.path.join(spec.backing_dir, f"range_{r:03d}")
            )
        return spec

    @classmethod
    def from_dense(cls, values: np.ndarray, spec: TieredSpec,
                   num_ranges: int, **kw) -> "ShardedTieredStore":
        values = np.asarray(values)
        n, m = values.shape
        dtype = values.dtype if spec.quant == "none" else np.float32
        store = cls(n, m, spec, num_ranges, dtype=dtype, **kw)
        for r, part in enumerate(store.parts):
            lo = r * store.rows_local
            part._fill_host(values[lo:lo + store.rows_local])
        return store

    # ------------------------------------------------------------- routing

    def _route(self, flat_idx: np.ndarray):
        """Yields (part, selection mask, local indices) for every range
        the flat global ids touch."""
        for r, part in enumerate(self.parts):
            lo = r * self.rows_local
            sel = (flat_idx >= lo) & (flat_idx < lo + self.rows_local)
            if sel.any():
                yield part, sel, (flat_idx[sel] - lo).astype(np.int64)

    # ------------------------------------------------------------- lookups

    def gather(self, idx, w) -> jax.Array:
        """sum_k w[..., k] * values[idx[..., k]] -> (..., m): per-range
        masked partial interpolations, summed (the local form of the
        sharded psum join).  Each range's partial runs through its own
        device cache — misses fill, overflow serves host-side, exactly as
        in the single-range tiered store."""
        idx_np = np.asarray(idx)
        lead, top_k = idx_np.shape[:-1], idx_np.shape[-1]
        flat = idx_np.reshape(-1)
        w_flat = np.asarray(w, np.float32).reshape(-1)
        tokens = flat.size // top_k
        token_of = np.arange(flat.size) // top_k
        out = np.zeros((tokens, self.m), np.float32)
        for part, sel, local in self._route(flat):
            # k=1 sub-gather per routed element; scatter-add into the
            # owning token's output row.  The sub-batch is padded to a
            # power-of-two bucket (weight-0 repeats of an in-range row, so
            # no extra shard is touched): the jitted device gather then
            # sees O(log batch) distinct shapes, not one compile per
            # distinct routed-element count.
            n = local.size
            pad = 1 << max(0, n - 1).bit_length()
            idx_pad = np.full(pad, local[0], np.int32)
            idx_pad[:n] = local
            w_pad = np.zeros(pad, np.float32)
            w_pad[:n] = w_flat[sel]
            # valid_elems: the weight-0 tail must not count as accesses
            partial = part.gather(
                idx_pad.reshape(-1, 1), w_pad.reshape(-1, 1), valid_elems=n
            )
            np.add.at(out, token_of[sel], np.asarray(partial)[:n])
        return jnp.asarray(out.reshape(*lead, self.m))

    def gather_rows_host(self, idx) -> np.ndarray:
        """values[idx] -> (idx.shape + (m,)) fp32 via each range's host
        cache mirror — the io_callback body of the traced lookup."""
        idx_np = np.asarray(idx)
        flat = idx_np.reshape(-1)
        rows = np.empty((flat.size, self.m), np.float32)
        for part, sel, local in self._route(flat):
            rows[sel] = part.gather_rows_host(local)
        return rows.reshape(*idx_np.shape, self.m)

    # ------------------------------------------------------------ training

    @property
    def writeback_lr(self) -> float:
        return self.parts[0].writeback_lr

    @writeback_lr.setter
    def writeback_lr(self, lr: float) -> None:
        for part in self.parts:
            part.writeback_lr = lr

    def apply_writeback(self, idx, wg) -> None:
        """Sparse SGD write-back, routed: each range applies only the
        updates for rows it owns (value gradients never cross ranges)."""
        idx_np = np.asarray(idx)
        flat = idx_np.reshape(-1)
        upd = np.asarray(wg, np.float32).reshape(-1, self.m)
        for part, sel, local in self._route(flat):
            part.apply_writeback(local, upd[sel])

    # -------------------------------------------------- cache management
    # Range fills overlap each other through a small thread pool: each
    # range owns disjoint state (its own host shards, cache mirror, LRU),
    # so per-range prefetches are embarrassingly parallel — the serve
    # thread no longer serialises R host-memcpy walks.  Stat counting is
    # unchanged: prefetch never touches hit/miss counters, and fills are
    # counted inside each part exactly as on the serial path.

    def _executor(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=min(8, self.num_ranges),
                thread_name_prefix="memstore-prefetch",
            )
        return self._pool

    def _fanout(self, calls) -> None:
        """Run (fn, kwargs) pairs, overlapped when there is more than one."""
        calls = list(calls)
        obs.gauge("memstore.prefetch_queue_depth").set(len(calls))
        if len(calls) <= 1:
            for fn, kw in calls:
                fn(**kw)
            return
        futs = [self._executor().submit(fn, **kw) for fn, kw in calls]
        for f in futs:
            f.result()

    def prefetch(self, idx, *, sync_device: bool = True) -> None:
        flat = np.asarray(idx).reshape(-1)
        self._fanout(
            (part.prefetch, dict(idx=local, sync_device=sync_device))
            for part, sel, local in self._route(flat)
        )

    def prefetch_last(self, *, sync_device: bool = False) -> None:
        self._fanout(
            (part.prefetch_last, dict(sync_device=sync_device))
            for part in self.parts
        )

    def warm(self, shards: Iterable[int] | None = None) -> None:
        if shards is None:
            for part in self.parts:
                part.warm()
            return
        per = self._shards_per_range
        for i in shards:
            self.parts[i // per].warm([i % per])

    def flush(self) -> None:
        for part in self.parts:
            part.flush()

    # --------------------------------------------------------------- stats

    @property
    def stats(self) -> dict:
        agg: dict = {}
        for part in self.parts:
            for k, v in part.stats.items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def reset_stats(self) -> None:
        for part in self.parts:
            part.reset_stats()

    def hit_rate(self) -> float:
        s = self.stats
        total = s["hits"] + s["misses"] + s["uncached"]
        return s["hits"] / total if total else 0.0

    def row_stats(self) -> tuple[np.ndarray, int]:
        """(per-shard access counts in global shard order, rows per shard):
        ranges are row-contiguous, so concatenating per-part counters IS
        the global shard axis (the checkpoint stream's shard order)."""
        return (np.concatenate([p.shard_access for p in self.parts]),
                self.shard_rows)

    # ------------------------------------------------------------- lifecycle

    def _read_rows_raw(self, rows: np.ndarray):
        """(payload, scales|None) for global row ids in storage form —
        routed to the owning ranges; see TieredValueStore._read_rows_raw."""
        flat = np.asarray(rows, np.int64).reshape(-1)
        if flat.size and (flat.min() < 0 or flat.max() >= self.num_rows):
            # an unrouted id would leave np.empty rows uninitialized
            raise ValueError("row ids must index the table")
        payload = np.empty((flat.size, self.m),
                           self.parts[0].storage_dtype
                           if self.quant != "none" else self.parts[0].dtype)
        scales = (np.empty(flat.size, np.float32)
                  if self.quant != "none" else None)
        for part, sel, local in self._route(flat):
            p, s = part._read_rows_raw(local)
            payload[sel] = p
            if scales is not None:
                scales[sel] = s
        return payload, scales

    def grow_rows(self, new_num_rows: int, parents: np.ndarray) -> None:
        """Append rows [num_rows, new_num_rows) as *new ranges* — in place.

        Existing ranges keep their row spans, host shards, and device
        caches untouched (the same append-only property as
        `TieredValueStore.grow_rows`); each appended range is a fresh
        tiered store of `rows_local` rows whose host tier is filled from
        the parent rows, inheriting the live `writeback_lr`.  Global shard
        ids extend contiguously, so grown checkpoints stay
        byte-compatible with plain tiered stores of the same layout.
        """
        delta = new_num_rows - self.num_rows
        if delta <= 0 or delta % self.rows_local:
            raise ValueError(
                f"new_num_rows={new_num_rows} must exceed {self.num_rows} "
                f"by a multiple of the range size {self.rows_local}"
            )
        parents = np.asarray(parents, np.int64).reshape(-1)
        if parents.size != delta:
            raise ValueError(f"need {delta} parent rows, got {parents.size}")
        if parents.size and (parents.min() < 0
                             or parents.max() >= self.num_rows):
            raise ValueError("parent row ids must index the old table")
        payload, scales = self._read_rows_raw(parents)
        lr = self.writeback_lr
        for k in range(delta // self.rows_local):
            r = self.num_ranges + k
            part = TieredValueStore(
                self.rows_local, self.m, self._part_spec(self.spec, r),
                dtype=self.dtype,
            )
            lo = k * self.rows_local
            pay3 = payload[lo:lo + self.rows_local].reshape(
                part.num_shards, part.shard_rows, self.m
            )
            part._host[...] = pay3
            if self.quant != "none":
                part._host_scale[...] = scales[
                    lo:lo + self.rows_local
                ].reshape(part.num_shards, part.shard_rows)
            part.writeback_lr = lr
            self.parts.append(part)
        self.num_rows = new_num_rows
        self.num_ranges = len(self.parts)
        self.num_shards = self.num_ranges * self._shards_per_range
        if self._pool is not None:  # resize the executor to the new fanout
            self._pool.shutdown(wait=False)
            self._pool = None

    def bytes_per_entry(self) -> int:
        return self.parts[0].bytes_per_entry()

    def resident_shards(self) -> list[int]:
        per = self._shards_per_range
        return [r * per + s
                for r, part in enumerate(self.parts)
                for s in part.resident_shards()]

    # ---------------------------------------------------------- checkpoint
    # global shard ids: shard i lives in range i // shards_per_range —
    # the on-disk stream is identical to a tiered store of the same
    # (num_shards, shard_rows, m), so tiered <-> sharded-tiered restore
    # is free (repro.checkpoint).

    def shard_host(self, i: int) -> np.ndarray:
        per = self._shards_per_range
        return self.parts[i // per].shard_host(i % per)

    def shard_scale_host(self, i: int) -> np.ndarray:
        per = self._shards_per_range
        return self.parts[i // per].shard_scale_host(i % per)

    def load_shard(self, i: int, arr: np.ndarray,
                   scale: np.ndarray | None = None) -> None:
        per = self._shards_per_range
        self.parts[i // per].load_shard(i % per, arr, scale)

    def load_dense(self, values: np.ndarray) -> None:
        values = np.asarray(values)
        if values.shape != (self.num_rows, self.m):
            raise ValueError(
                f"shape {values.shape} != {(self.num_rows, self.m)}"
            )
        for r, part in enumerate(self.parts):
            lo = r * self.rows_local
            part.load_dense(values[lo:lo + self.rows_local])

    def to_dense(self) -> np.ndarray:
        return np.concatenate([part.to_dense() for part in self.parts])

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"ShardedTieredStore(rows={self.num_rows}, m={self.m}, "
            f"ranges={self.num_ranges}x{self.rows_local}, "
            f"quant={self.quant!r}, hit_rate={self.hit_rate():.3f})"
        )


# Leafless pytree node, like TieredValueStore: rides params untouched.
jax.tree_util.register_pytree_node(
    ShardedTieredStore,
    lambda s: ((), s),
    lambda aux, children: aux,
)
lookup.register_store_type(ShardedTieredStore)


# ---------------------------------------------------------------------------
# placement backends (repro.core.lookup registry)
# ---------------------------------------------------------------------------

def _sharded_factory(cfg, storage: str, kernel: str) -> lookup.LookupPlan:
    mesh = _ctx.get_mesh()
    if mesh is None or AXIS not in mesh.axis_names:
        raise lookup.LookupPlanError(
            "sharded", storage, kernel,
            f"needs an ambient mesh with a {AXIS!r} axis — call "
            "repro.distributed.context.set_mesh(mesh) before resolving",
        )
    n_shards = mesh.shape[AXIS]
    if cfg.num_locations % n_shards:
        raise lookup.LookupPlanError(
            "sharded", storage, kernel,
            f"num_locations={cfg.num_locations} not divisible by the "
            f"{AXIS!r} axis size {n_shards}",
        )
    hook = sharded_gather_interp(mesh, axis=AXIS, kernel=kernel)

    if storage == "fp32":
        def build_table(dense):
            return dense

        def interp(values, idx, w):
            if lookup.is_store(values) or isinstance(values, QuantizedTable):
                raise lookup.LookupPlanError(
                    "sharded", storage, kernel,
                    f"expected a dense fp32 table, got "
                    f"{type(values).__name__}",
                )
            return hook(values, idx, w)

        return lookup.LookupPlan(
            placement="sharded", storage=storage, kernel=kernel,
            build_table=build_table, interp=interp, requires_mesh=True,
            # growing a mesh-sharded dense table means resharding live
            # device buffers — a relaunch (or a migration to
            # sharded-tiered) is the supported path
            supports_growth=False, table_rows_axis=AXIS,
        )

    def build_table_q(dense):
        return QuantizedTable.from_dense(dense, storage)

    def interp_q(values, idx, w):
        if not isinstance(values, QuantizedTable):
            raise lookup.LookupPlanError(
                "sharded", storage, kernel,
                f"expected a QuantizedTable, got {type(values).__name__}",
            )
        return hook(values, idx, w)

    return lookup.LookupPlan(
        placement="sharded", storage=storage, kernel=kernel,
        build_table=build_table_q, interp=interp_q,
        table_update="frozen", requires_mesh=True,
        supports_growth=False, table_rows_axis=AXIS,
    )


def _sharded_tiered_factory(cfg, storage: str,
                            kernel: str) -> lookup.LookupPlan:
    spec = lookup.merged_tiered_spec(cfg, storage, kernel)
    mesh = _ctx.get_mesh()
    num_ranges = cfg.model_shards
    if num_ranges <= 0:
        num_ranges = (mesh.shape[AXIS]
                      if mesh is not None and AXIS in mesh.axis_names else 1)
    if cfg.num_locations % num_ranges:
        raise lookup.LookupPlanError(
            "sharded-tiered", storage, kernel,
            f"num_locations={cfg.num_locations} not divisible by "
            f"model_shards={num_ranges}",
        )
    if (cfg.num_locations // num_ranges) % spec.shard_rows:
        raise lookup.LookupPlanError(
            "sharded-tiered", storage, kernel,
            f"range size {cfg.num_locations // num_ranges} not divisible "
            f"by TieredSpec.shard_rows={spec.shard_rows}",
        )

    def build_table(dense):
        return ShardedTieredStore.from_dense(
            np.asarray(dense), spec, num_ranges
        )

    def interp(values, idx, w):
        if not isinstance(values, ShardedTieredStore):
            raise lookup.LookupPlanError(
                "sharded-tiered", storage, kernel,
                "params['values'] must be a ShardedTieredStore — init the "
                "layer with LRAMConfig(interp_impl='sharded-tiered')",
            )
        from repro.memstore import tiered_interp

        return tiered_interp(values, idx, w)

    return lookup.LookupPlan(
        placement="sharded-tiered", storage=storage, kernel=kernel,
        build_table=build_table, interp=interp,
        supports_prefetch=True, table_update="writeback",
        checkpoint_layout="shards",
        supports_growth=True, row_stats=True,
        build_empty=lambda: ShardedTieredStore(
            cfg.num_locations, cfg.m, spec, num_ranges
        ),
        # single-process row-range store: base rows are host-readable, so
        # the per-tenant overlay composes (the mesh-sharded dense plan
        # above stays overlay-free: its rows live in device shards)
        supports_overlay=True,
    )


lookup.register_placement("sharded", _sharded_factory)
lookup.register_placement("sharded-tiered", _sharded_tiered_factory)
