"""Model-parallel LRAM lookup: masked local gather + one psum.

THE key TPU-native re-think of the paper's random-access memory (DESIGN.md
§3): the value table's rows are sharded over the `model` mesh axis.  Instead
of cross-chip random access (ruinous on TPU interconnects), every device

  1. receives the full (replicated-over-model) index/weight sets,
  2. gathers ONLY indices that fall inside its row shard (others masked to
     weight zero, index clamped),
  3. partially interpolates, and
  4. joins the partial outputs with a single psum over `model`.

Communication is O(tokens * heads * m) — *independent of N* — identical in
shape to a tensor-parallel FFN's reduce.  The O(1)-in-N property of the
paper survives sharding.  The backward pass (autodiff through shard_map)
scatter-adds only into local rows: value-table gradients never cross the
model axis at all.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed._compat import shard_map
from repro.quant import QuantizedTable, dequantize_rows


def sharded_gather_interp(mesh: Mesh, *, axis: str = "model"):
    """Returns an `interp_impl` hook (values, idx, w) -> out for lram_apply.

    values must be laid out P(axis, None); idx/w replicated along `axis`
    (they are functions of activations, which are batch-sharded on `data`).
    `values` may also be a `repro.quant.QuantizedTable`: its payload and
    per-row scales shard over the same axis, each device dequantizes only
    the rows it gathers locally, and the psum'd partials are unchanged —
    quantization is invisible to the collective.
    """
    n_shards = mesh.shape[axis]
    other = tuple(a for a in mesh.axis_names if a != axis)
    act_spec = P(other if len(other) > 1 else (other[0] if other else None))

    def interp(values, idx, w):
        quantized = isinstance(values, QuantizedTable)
        table = values.q if quantized else values
        rows_local = table.shape[0] // n_shards

        def local_rows(values_l, idx_l):
            base = jax.lax.axis_index(axis) * rows_local
            rel = idx_l - base
            ok = (rel >= 0) & (rel < rows_local)
            rel_safe = jnp.clip(rel, 0, rows_local - 1)
            return rel_safe, ok

        def local(values_l, idx_l, w_l):
            rel_safe, ok = local_rows(values_l, idx_l)
            rows = jnp.take(values_l, rel_safe, axis=0).astype(w_l.dtype)
            wm = w_l * ok.astype(w_l.dtype)
            out = jnp.einsum("...k,...km->...m", wm, rows)
            return jax.lax.psum(out, axis)

        def local_quant(values_l, scale_l, idx_l, w_l):
            rel_safe, ok = local_rows(values_l, idx_l)
            rows = dequantize_rows(  # in-shard dequant, fp32 partials
                jnp.take(values_l, rel_safe, axis=0),
                jnp.take(scale_l, rel_safe, axis=0),
            ).astype(w_l.dtype)
            wm = w_l * ok.astype(w_l.dtype)
            out = jnp.einsum("...k,...km->...m", wm, rows)
            return jax.lax.psum(out, axis)

        dim_spec = act_spec[0] if len(act_spec) else None
        io_spec = P(*((dim_spec,) + (None,) * (idx.ndim - 1)))
        if quantized:
            return shard_map(
                local_quant,
                mesh=mesh,
                in_specs=(P(axis, None), P(axis), io_spec, io_spec),
                out_specs=io_spec,
                check_vma=False,
            )(values.q, values.scale, idx, w)
        return shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axis, None), io_spec, io_spec),
            out_specs=io_spec,
            check_vma=False,
        )(values, idx, w)

    return interp
