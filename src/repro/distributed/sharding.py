"""GSPMD partition rules: hybrid FSDP(data) x TP(model) x EP, MaxText-style.

Param placement is decided by regex match on the flattened tree path; the
matched spec describes the *trailing* dims (scanned segments carry leading
layer/unit dims, padded with None).  On the multi-pod mesh the FSDP axis is
("pod", "data") — pods extend data parallelism; `model` stays intra-pod
(ICI-local), which is what keeps the collective roofline term sane: TP
collectives never cross the pod axis.

Divisibility-aware: any rule whose axis does not divide the dim falls back
to replication for that dim (e.g. kv_heads=2 cannot shard over model=16, so
decode caches shard head_dim instead — see cache_pspecs).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    fsdp: tuple[str, ...] = ("data",)
    tp: str = "model"

    @classmethod
    def for_mesh(cls, mesh: Mesh) -> "MeshAxes":
        if "pod" in mesh.axis_names:
            return cls(fsdp=("pod", "data"), tp="model")
        return cls(fsdp=("data",), tp="model")


def _rules(ax: MeshAxes) -> list[tuple[str, tuple]]:
    F, T = ax.fsdp, ax.tp
    return [
        # embeddings: vocab on TP, feature on FSDP
        (r"embed/embedding$", (T, F)),
        (r"pos_embed$", (None, None)),
        (r"enc_pos_embed$", (None, None)),
        (r"lm_head/kernel$", (F, T)),
        # attention
        (r"(attn|cross)/wq/kernel$", (F, T)),
        (r"(attn|cross)/wk/kernel$", (F, T)),
        (r"(attn|cross)/wv/kernel$", (F, T)),
        (r"(attn|cross)/wo/kernel$", (T, F)),
        (r"(attn|cross)/w[qkv]/bias$", (T,)),
        # dense mlp
        (r"mlp/wi(_gate|_up)?/kernel$", (F, T)),
        (r"mlp/wo/kernel$", (T, F)),
        (r"mlp/w[io].*?/bias$", (None,)),
        # MoE: experts on TP axis (expert parallelism) when E divides the
        # axis; otherwise Megatron-style TP *within* each expert (hidden dim
        # column/row sharded, one psum per layer).  The naive fallback
        # (replicate E, FSDP the contracting dim) produced a 42 TiB/step
        # all-reduce on mixtral (E=8 < model=16) — see EXPERIMENTS.md §Perf.
        (r"moe/router/kernel$", (F, None)),
        (r"moe/experts/wi(_gate|_up)?$", [(T, F, None), (None, F, T)]),
        (r"moe/experts/wi$", [(T, F, None), (None, F, T)]),
        (r"moe/experts/wo$", [(T, None, F), (None, T, F)]),
        # mamba
        (r"mamba/in_proj/kernel$", (F, T)),
        (r"mamba/out_proj/kernel$", (T, F)),
        (r"mamba/conv$", (None, T)),
        (r"mamba/(A_log|D|dt_bias)$", (None,)),
        (r"mamba/norm/scale$", (T,)),
        # LRAM memory tables carry NO rule here: the resolved LookupPlan
        # emits their placement directly (`table_rows_axis` —
        # `_memory_table_spec` below).  Dense plans replicate the table +
        # shard heads on TP, exactly a TP-FFN's collective shape
        # (EXPERIMENTS.md §Perf cell 3); the sharded plan rows-shards it
        # over `model`; tiered plans keep it host-side (leafless).
        (r"pkm/values$", (T, None)),
        (r"pkm/subkeys[12]$", (None, T, None)),
        (r"pkm/query/kernel$", (F, T)),
        (r"memffn/wi/kernel$", (F, T)),
        (r"memffn/wo/kernel$", (T, F)),
        # norms, biases, batchnorm state: replicated
        (r".*", None),
    ]


def _apply_spec(spec: tuple, ndim: int, shape, mesh: Mesh):
    """Left-pad for stacked (scan) leading dims + per-dim divisibility."""
    spec = (None,) * (ndim - len(spec)) + tuple(spec)
    fixed, clean = [], True
    for dim, s in zip(shape, spec):
        if s is None:
            fixed.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % size == 0:
            fixed.append(s)
        else:
            fixed.append(None)
            clean = False
    return P(*fixed), clean


def _spec_for(name: str, ndim: int, shape, mesh: Mesh,
              ax: MeshAxes) -> P:
    for pat, spec in _rules(ax):
        if re.search(pat, name):
            if spec is None:
                return P()
            candidates = spec if isinstance(spec, list) else [spec]
            best = None
            for cand in candidates:
                p, clean = _apply_spec(cand, ndim, shape, mesh)
                if best is None:
                    best = p
                if clean:
                    return p
            return best
    return P()


def _memory_table_spec(plan, ndim: int, shape, mesh: Mesh) -> P:
    """The LRAM value table's pspec, emitted by its resolved LookupPlan:
    `table_rows_axis` names the mesh axis the leading (row) axis shards
    over (None = replicate).  Applies uniformly to every table leaf — the
    fp32 array (N, m), a QuantizedTable's payload (N, m), and its per-row
    scales (N,) — since all of them are row-major over the same N."""
    axis = plan.table_rows_axis
    if axis is None or axis not in mesh.axis_names:
        return P()
    spec, _ = _apply_spec(
        (axis,) + (None,) * (ndim - 1), ndim, shape, mesh
    )
    return spec


def param_pspecs(params, mesh: Mesh,
                 ax: Optional[MeshAxes] = None, *, model_cfg=None):
    """Pytree of PartitionSpec mirroring `params`.

    `model_cfg` (a ModelConfig) lets the resolved lookup plan place the
    memory tables (`lram/values` leaves) instead of a path-regex rule —
    required for row-sharded tables (`interp_impl="sharded"`), harmless
    otherwise (dense plans replicate, matching the regex-era default)."""
    ax = ax or MeshAxes.for_mesh(mesh)
    mem_plan = None
    if model_cfg is not None:
        from repro.core import lookup

        plans = lookup.model_plans(model_cfg)
        mem_plan = plans[0] if plans else None
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if mem_plan is not None and "lram/values" in name:
            specs.append(
                _memory_table_spec(mem_plan, leaf.ndim, leaf.shape, mesh)
            )
            continue
        specs.append(_spec_for(name, leaf.ndim, leaf.shape, mesh, ax))
    return jax.tree_util.tree_unflatten(treedef, specs)


def shard_params(params, mesh: Mesh, *, model_cfg=None):
    specs = param_pspecs(params, mesh, model_cfg=model_cfg)
    return jax.tree.map(
        lambda p, s: jax.device_put(p, NamedSharding(mesh, s)), params, specs
    )


def batch_pspec(mesh: Mesh) -> P:
    """Input batches: global batch over (pod?, data)."""
    ax = MeshAxes.for_mesh(mesh)
    return P(ax.fsdp if len(ax.fsdp) > 1 else ax.fsdp[0])


def _shard_dim(dim: int, axis: str, mesh: Mesh):
    return axis if dim % mesh.shape[axis] == 0 else None


def cache_pspecs(cache_like, cfg, mesh: Mesh):
    """Decode-cache placement with divisibility fallbacks, keyed by the
    cache-entry name (structural, not shape-guessing):

      k/v/ck/cv  (..., B, T, Kh, D): B->data when divisible (else T->data,
                 the long_500k B=1 case); Kh->model, else D->model (low-kv
                 GQA archs: kv=2 cannot split 16 ways, head_dim=128 can).
      ssm        (..., B, H, N, P): B->data, H->model.
      conv       (..., B, W, C):    B->data, C->model.
    """
    ax = MeshAxes.for_mesh(mesh)
    data_ax = ax.fsdp[-1]
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_like)
    specs = []
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        shape, nd = leaf.shape, leaf.ndim
        if name in ("k", "v", "ck", "cv"):
            b, t, kh, d = shape[-4], shape[-3], shape[-2], shape[-1]
            sb = _shard_dim(b, data_ax, mesh)
            st = _shard_dim(t, data_ax, mesh) if sb is None else None
            skh = _shard_dim(kh, ax.tp, mesh)
            sd = None if skh else _shard_dim(d, ax.tp, mesh)
            specs.append(P(*(None,) * (nd - 4), sb, st, skh, sd))
        elif name == "ssm":
            b, h = shape[-4], shape[-3]
            specs.append(P(
                *(None,) * (nd - 4),
                _shard_dim(b, data_ax, mesh),
                _shard_dim(h, ax.tp, mesh), None, None,
            ))
        elif name == "conv":
            b, c = shape[-3], shape[-1]
            specs.append(P(
                *(None,) * (nd - 3),
                _shard_dim(b, data_ax, mesh), None,
                _shard_dim(c, ax.tp, mesh),
            ))
        else:
            specs.append(P())
    return jax.tree_util.tree_unflatten(treedef, specs)
