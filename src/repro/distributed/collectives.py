"""Explicit-collective building blocks (shard_map level).

`compressed_psum` is the wire-form of the gradient-compression trick: every
shard quantizes against a common scale (one pmax of a scalar), the int8
payload crosses the interconnect (4x fewer bytes than f32 on the DP
all-reduce — the term that dominates the multi-pod collective roofline),
and the sum is dequantized on arrival.  Error feedback lives one level up
(repro.optim.compression).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """Common-scale int8 all-reduce over a mesh axis (use inside shard_map).

    Accumulates in int32 (worst case 127 * axis_size << 2^31), returns the
    dequantized f32 sum.  Quantization error is bounded by
    scale/2 * axis_size; pair with error feedback upstream."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    scale_all = jax.lax.pmax(scale, axis)
    q = jnp.clip(jnp.round(x / scale_all), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale_all
