"""Multi-device substrates: meshes, sharded lookup, pipeline, fault.

Public surface: sharding rules re-exported below (`MeshAxes`,
`param_pspecs`, `batch_pspec`, `cache_pspecs`, `shard_params`), plus one
module per concern — `repro.distributed.sharded_lram` (model-parallel
LRAM lookup, quantization-aware), `pipeline` (GPipe over a mesh axis),
`collectives` (compressed psum), `context` (mesh-scoped activation
constraints), `fault` (heartbeat/straggler monitors), `_compat`
(shard_map across jax versions).
"""

from repro.distributed.sharding import (  # noqa: F401
    MeshAxes,
    batch_pspec,
    cache_pspecs,
    param_pspecs,
    shard_params,
)
