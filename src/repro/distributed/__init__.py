from repro.distributed.sharding import (  # noqa: F401
    MeshAxes,
    batch_pspec,
    cache_pspecs,
    param_pspecs,
    shard_params,
)
