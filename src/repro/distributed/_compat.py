"""shard_map across jax versions.

jax >= 0.6 exports `jax.shard_map` with a `check_vma` keyword; earlier
releases (this container ships 0.4.x) keep it in `jax.experimental` with
the equivalent knob spelled `check_rep`.  `shard_map(...)` here accepts
the modern signature and rewrites the keyword when running on old jax.
"""

from __future__ import annotations

try:
    from jax import shard_map as _shard_map

    _LEGACY = False
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

    _LEGACY = True


def shard_map(f, /, *, check_vma: bool = True, **kw):
    if _LEGACY:
        kw["check_rep"] = check_vma
    else:
        kw["check_vma"] = check_vma
    return _shard_map(f, **kw)
