"""Fault-tolerance runtime pieces: heartbeats, stragglers, failure injection.

On a real fleet these hooks feed the cluster scheduler; here they are fully
implemented and unit-tested against simulated timings, and the train driver
wires them in (`--simulate-failure-at`, straggler report in the step log).
"""

from __future__ import annotations

import dataclasses
import time


class SimulatedFailure(RuntimeError):
    """Raised by the train driver to simulate a node crash."""


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks per-host step heartbeats; flags missing or straggling hosts.

    EWMA of per-host step durations; a host is a *straggler* when its EWMA
    exceeds `straggler_factor` x the fleet median, and *dead* when no
    heartbeat arrives within `timeout_s`.
    """

    num_hosts: int
    straggler_factor: float = 1.5
    timeout_s: float = 60.0
    alpha: float = 0.3

    def __post_init__(self):
        self._ewma: dict[int, float] = {}
        self._last_seen: dict[int, float] = {}

    def heartbeat(self, host: int, step_duration: float,
                  now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        prev = self._ewma.get(host)
        self._ewma[host] = (
            step_duration if prev is None
            else self.alpha * step_duration + (1 - self.alpha) * prev
        )
        self._last_seen[host] = now

    def fleet_median(self) -> float:
        vals = sorted(self._ewma.values())
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def stragglers(self) -> list[int]:
        med = self.fleet_median()
        if med <= 0:
            return []
        return sorted(
            h for h, v in self._ewma.items()
            if v > self.straggler_factor * med
        )

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        seen = set(self._last_seen)
        missing = [h for h in range(self.num_hosts) if h not in seen]
        timed_out = [
            h for h, t in self._last_seen.items()
            if now - t > self.timeout_s
        ]
        return sorted(missing + timed_out)

    def healthy(self, now: float | None = None) -> bool:
        return not self.dead_hosts(now)


@dataclasses.dataclass
class StepTimer:
    """Per-step wall-time stats with outlier (straggler-step) detection."""

    window: int = 50

    def __post_init__(self):
        self.durations: list[float] = []

    def record(self, seconds: float) -> None:
        self.durations.append(seconds)
        if len(self.durations) > self.window:
            self.durations.pop(0)

    def median(self) -> float:
        s = sorted(self.durations)
        return s[len(s) // 2] if s else 0.0

    def is_outlier(self, seconds: float, factor: float = 2.0) -> bool:
        med = self.median()
        return med > 0 and seconds > factor * med
