"""Ambient mesh context for in-model sharding constraints.

GSPMD occasionally resolves an einsum by exploiting whatever dim happens to
be sharded (e.g. contracting over an FSDP-sharded weight dim, all-reducing
activation-sized partials — the mixtral pathology in EXPERIMENTS.md §Perf).
Model code can pin activation layouts with `constrain(x, ...spec)`; it is a
no-op when no mesh is registered (single-device tests) or when a dim is not
divisible by its axis.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def batch_axes() -> tuple:
    """The data-parallel axis spec entry for the current mesh."""
    if _MESH is None:
        return None
    if "pod" in _MESH.axis_names:
        return ("pod", "data")
    return "data"


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint(x, P(*spec)) under the ambient mesh.

    Per-dim divisibility fallback (entry -> None when the dim does not
    divide the axis product); no-op without a mesh."""
    if _MESH is None:
        return x
    fixed = []
    for dim, s in zip(x.shape, spec):
        if s is None:
            fixed.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        if any(a not in _MESH.axis_names for a in axes):
            fixed.append(None)
            continue
        size = int(np.prod([_MESH.shape[a] for a in axes]))
        fixed.append(s if dim % size == 0 else None)
    fixed += [None] * (x.ndim - len(fixed))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*fixed))
    )
