"""Pallas TPU kernels for the bandwidth-critical lookup hot path.

Public surface:

  * `repro.kernels.e8_lookup`     — query kernel: distance matmul over the
    232 candidates + unrolled top-k (`lram_query_pallas`)
  * `repro.kernels.gather_interp` — scalar-prefetch row gather + weighted
    interpolation (`gather_interp_pallas`, differentiable
    `gather_interp_vjp`); fused-dequant variants for quantized tables
    (`gather_interp_quant_pallas`, differentiable `gather_interp_quant`)
  * `repro.kernels.tiered_gather` — gather through the tiered store's
    shard->slot indirection (`tiered_gather_pallas`, quantized
    `tiered_gather_quant_pallas`, jnp references)
  * `repro.kernels.ops`           — `lram_lookup`: query + gather fused
    behind one custom_vjp (sparse scatter-add backward), and the legacy
    `make_interp_impl` callable hook (deprecated)
  * `repro.kernels.ref`           — jnp references for every kernel

`gather_interp` and `ref` register the "pallas" / "reference" kernel
cells of the lookup-plan registry, and `tiered_gather` the indirected
cells (`repro.core.lookup` resolves them lazily).  On CPU the kernels
run in Pallas interpret mode; on TPU they JIT to Mosaic.  Placement in
the overall system: docs/architecture.md.
"""
