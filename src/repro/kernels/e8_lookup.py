"""Fused LRAM query kernel (Pallas TPU).

One kernel performs, per query tile, the paper's whole CUDA §2.6 pipeline:

  1. E8 nearest-point decode (both D8 cosets, branch-free),
  2. canonicalization into the fundamental region F via a 19-comparator
     Batcher sorting network (data lives as (8, TILE_B): coordinates on
     sublanes, queries on lanes — every compare-exchange is a full-vector op),
  3. squared distances to all 232 candidates as ONE (256, 8) x (8, TILE_B)
     MXU matmul (table zero-padded to 256 rows),
  4. kernel weights f(d^2) = relu(1 - d^2/8)^4,
  5. top-32 selection as 32 unrolled masked-argmax steps (no warp shuffles on
     TPU; masked reductions are the idiom),
  6. inverse isometry + O(1) torus index encode for the selected points
     (integer row ops).

VMEM budget per tile (TILE_B = 128): queries 4 KiB, candidate table 8 KiB,
score matrix (256 x 128 f32) 128 KiB, assorted rows < 64 KiB — far under the
~16 MiB/core budget, so the grid only tiles the query axis.

The GPU original uses one thread per query with a per-thread heap; none of
that survives on TPU — see DESIGN.md §3 for the adaptation rationale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import indexing, lattice

TILE_B = 128
NUM_PADDED = 256  # candidate table padded to an MXU-friendly row count

# Batcher odd-even mergesort network for 8 inputs (19 comparators).
SORT_NETWORK: tuple[tuple[int, int], ...] = (
    (0, 1), (2, 3), (4, 5), (6, 7),
    (0, 2), (1, 3), (4, 6), (5, 7),
    (1, 2), (5, 6),
    (0, 4), (1, 5), (2, 6), (3, 7),
    (2, 4), (3, 5),
    (1, 2), (3, 4), (5, 6),
)


def _padded_candidates() -> tuple[np.ndarray, np.ndarray]:
    cand, nsq = lattice.candidate_arrays()
    pad = NUM_PADDED - cand.shape[0]
    cand_p = np.concatenate([cand, np.zeros((pad, 8), np.float32)], 0)
    nsq_p = np.concatenate([nsq, np.zeros((pad,), np.float32)], 0)
    valid = np.concatenate(
        [np.ones((cand.shape[0],), np.float32), np.zeros((pad,), np.float32)]
    )
    return cand_p, nsq_p, valid


def _decode_d8_rows(u):
    """Nearest D8 point; u is (8, B) with coordinates on the sublane axis."""
    r = jnp.round(u)
    delta = u - r
    worst = jnp.argmax(jnp.abs(delta), axis=0)  # (B,)
    rows = jax.lax.broadcasted_iota(jnp.int32, u.shape, 0)
    onehot = (rows == worst[None, :]).astype(u.dtype)
    flip = jnp.where(delta >= 0, 1.0, -1.0)
    r_alt = r + onehot * flip
    odd = jnp.mod(jnp.sum(r, axis=0), 2.0) != 0  # (B,)
    return jnp.where(odd[None, :], r_alt, r)


def _decode_rows(q):
    even = 2.0 * _decode_d8_rows(q * 0.5)
    odd = 2.0 * _decode_d8_rows((q - 1.0) * 0.5) + 1.0
    de = jnp.sum((q - even) ** 2, axis=0)
    do = jnp.sum((q - odd) ** 2, axis=0)
    return jnp.where((de <= do)[None, :], even, odd)


def _sort_rows_desc(keys, payloads):
    """Sort 8 rows by descending key via the fixed comparator network.

    payloads is a list of (8, B) arrays permuted alongside the keys.
    """
    rows = [keys[i] for i in range(8)]
    pls = [[p[i] for i in range(8)] for p in payloads]
    for i, j in SORT_NETWORK:
        swap = rows[i] < rows[j]  # descending order
        ri, rj = rows[i], rows[j]
        rows[i] = jnp.where(swap, rj, ri)
        rows[j] = jnp.where(swap, ri, rj)
        for p in pls:
            pi, pj = p[i], p[j]
            p[i] = jnp.where(swap, pj, pi)
            p[j] = jnp.where(swap, pi, pj)
    return (
        jnp.stack(rows, axis=0),
        [jnp.stack(p, axis=0) for p in pls],
    )


def _encode_rows(x_int, K: tuple[int, ...]):
    """O(1) torus index from integer lattice coords (8, B) — see indexing.py."""
    M = [k // 2 for k in K]
    xm = [jnp.mod(x_int[i], K[i]) for i in range(8)]
    pbit = xm[0] & 1
    u = [(xm[i] - pbit) >> 1 for i in range(8)]
    qpar = functools.reduce(lambda a, b: a + b, u[:7]) & 1
    j8 = (u[7] - qpar) >> 1
    idx7 = u[0]
    for i in range(1, 7):
        idx7 = idx7 * M[i] + u[i]
    return (idx7 * (M[7] >> 1) + j8) * 2 + pbit


def _query_kernel(q_ref, cand_ref, aux_ref, idx_ref, w_ref,
                  *, K: tuple[int, ...], top_k: int):
    cand = cand_ref[...]                   # (256, 8)
    cand_nsq = aux_ref[0, :]               # (256,)
    valid = aux_ref[1, :]                  # (256,)

    q = q_ref[...].astype(jnp.float32).T   # (8, B)
    c = _decode_rows(q)
    t = q - c
    iota8 = jax.lax.broadcasted_iota(jnp.int32, t.shape, 0)
    keys, (tsort, perm) = _sort_rows_desc(jnp.abs(t), [t, iota8])
    sgn = jnp.where(tsort < 0, -1.0, 1.0)
    parity = jnp.prod(sgn, axis=0, keepdims=True)
    sgn = jnp.concatenate([sgn[:7], sgn[7:] * parity], axis=0)
    z = sgn * tsort                         # (8, B), lies in F

    # distances to all candidates: one MXU matmul
    cross = jnp.dot(cand, z, preferred_element_type=jnp.float32)  # (256, B)
    znorm = jnp.sum(z * z, axis=0, keepdims=True)                 # (1, B)
    d2 = znorm - 2.0 * cross + cand_nsq[:, None]
    relu = jnp.maximum(0.0, 1.0 - d2 / lattice.RADIUS_SQ)
    w_all = (relu * relu) * (relu * relu)
    scores = jnp.where(valid[:, None] > 0, w_all, -1.0)           # (256, B)

    idx_cols, w_cols = [], []
    for _ in range(top_k):
        m = jnp.max(scores, axis=0)                               # (B,)
        am = jnp.argmax(scores, axis=0)                           # (B,)
        rows256 = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
        onehot = (rows256 == am[None, :]).astype(jnp.float32)     # (256, B)
        # gather the selected candidate's canonical coords via MXU
        p_canon = jnp.dot(cand.T, onehot,
                          preferred_element_type=jnp.float32)     # (8, B)
        p_signed = sgn * p_canon
        # inverse permutation: g[perm_j] = p_signed_j
        g_rows = []
        for i in range(8):
            sel = (perm == i).astype(jnp.float32)
            g_rows.append(jnp.sum(sel * p_signed, axis=0))
        g = jnp.stack(g_rows, axis=0)                             # (8, B)
        k_glob = jnp.round(c + g).astype(jnp.int32)
        idx_cols.append(_encode_rows(k_glob, K))
        w_cols.append(jnp.maximum(m, 0.0))
        scores = jnp.where(onehot > 0, -1.0, scores)

    idx_ref[...] = jnp.stack(idx_cols, axis=-1)                   # (B, k)
    w_ref[...] = jnp.stack(w_cols, axis=-1)


def lram_query_pallas(
    q: jax.Array,
    spec: indexing.TorusSpec,
    top_k: int = lattice.DEFAULT_TOP_K,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(idx, w) = top-k lattice memory slots + kernel weights for q (..., 8).

    Non-differentiable by itself — repro.kernels.ops wraps it in the
    custom_vjp that implements the paper's analytic dw/dq backward.
    """
    lead = q.shape[:-1]
    qf = q.reshape(-1, 8).astype(jnp.float32)
    n = qf.shape[0]
    n_pad = -n % TILE_B
    qf = jnp.pad(qf, ((0, n_pad), (0, 0)))
    grid = (qf.shape[0] // TILE_B,)
    kern = functools.partial(_query_kernel, K=spec.K, top_k=top_k)
    cand_np, nsq_np, valid_np = _padded_candidates()
    cand = jnp.asarray(cand_np)
    aux = jnp.asarray(np.stack([nsq_np, valid_np]))  # (2, 256)
    idx, w = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, 8), lambda i: (i, 0)),
            pl.BlockSpec((NUM_PADDED, 8), lambda i: (0, 0)),
            pl.BlockSpec((2, NUM_PADDED), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_B, top_k), lambda i: (i, 0)),
            pl.BlockSpec((TILE_B, top_k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qf.shape[0], top_k), jnp.int32),
            jax.ShapeDtypeStruct((qf.shape[0], top_k), jnp.float32),
        ],
        interpret=interpret,
    )(qf, cand, aux)
    idx = idx[:n].reshape(*lead, top_k)
    w = w[:n].reshape(*lead, top_k)
    return idx, w
