"""Indirected value gather through the hot-shard cache (Pallas TPU).

Companion to `repro.kernels.gather_interp`: same bandwidth-critical
weighted gather, but the table operand is the *device cache* of a
`repro.memstore.TieredValueStore` — (cache_slots * shard_rows, m) — and the
global row id is translated on the fly through the shard->slot indirection
table:

    cache_row(r) = slot_table[r >> log2(shard_rows)] * shard_rows
                   + (r & (shard_rows - 1))

Both the flat index array AND the indirection table ride the scalar-prefetch
mechanism: they land in SMEM before the kernel runs, so the BlockSpec
index_map can chase the indirection and DMA exactly one cached value row
HBM->VMEM per grid step.  The translation is a shift/mask/multiply on SMEM
scalars — the grid sequencer hides it behind the row DMA, so indirection
adds no per-step latency over the dense gather kernel.

All indices must be cache-resident (slot_table[shard] >= 0) — the store
guarantees this by pinning the current batch's shards and serving overflow
rows host-side before choosing this kernel.

On CPU this runs in interpret mode; on real TPUs it JITs to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import lookup


def _kernel(idx_ref, slot_ref, w_ref, row_ref, out_ref):
    del idx_ref, slot_ref  # consumed by the index_map
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += w_ref[0, k] * row_ref[...].astype(out_ref.dtype)


def tiered_gather_pallas(
    cache_flat: jax.Array,
    idx: jax.Array,
    slot_table: jax.Array,
    w: jax.Array,
    *,
    shard_rows: int,
    interpret: bool = False,
) -> jax.Array:
    """sum_k w[..., k] * cache_flat[indirect(idx[..., k])] -> (..., m).

    Args:
      cache_flat: (cache_slots * shard_rows, m) device cache, flattened.
      idx: (..., top_k) int32 *global* row ids (all cache-resident).
      slot_table: (num_shards,) int32 shard -> slot indirection (-1 absent).
      w: (..., top_k) interpolation weights.
      shard_rows: rows per shard (power of two; fixes the shift/mask).
    """
    if shard_rows & (shard_rows - 1):
        raise ValueError("shard_rows must be a power of two")
    log2r = shard_rows.bit_length() - 1
    lead = idx.shape[:-1]
    top_k = idx.shape[-1]
    m = cache_flat.shape[-1]
    idx_flat = idx.reshape(-1, top_k).astype(jnp.int32)
    w_flat = w.reshape(-1, top_k).astype(jnp.float32)
    n = idx_flat.shape[0]

    def _row_index(t, k, idx_sref, slot_sref):
        gid = idx_sref[t, k]
        slot = slot_sref[gid >> log2r]
        return (slot * shard_rows + (gid & (shard_rows - 1)), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n, top_k),
        in_specs=[
            pl.BlockSpec((1, top_k), lambda t, k, idx_sref, slot_sref: (t, 0)),
            pl.BlockSpec((1, m), _row_index),
        ],
        out_specs=pl.BlockSpec(
            (1, m), lambda t, k, idx_sref, slot_sref: (t, 0)
        ),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(idx_flat, slot_table.astype(jnp.int32), w_flat, cache_flat)
    return out.reshape(*lead, m)


def _kernel_quant(idx_ref, slot_ref, w_ref, row_ref, scale_ref, out_ref):
    del idx_ref, slot_ref  # consumed by the index_map
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    # fused dequant: the cached row arrives in its 1-byte form; its fp32
    # scale rides a (1, 1) block through the same indirected index_map, so
    # the accumulate stays fp32 while the row DMA shrinks 4x
    out_ref[...] += (w_ref[0, k] * scale_ref[0, 0]) \
        * row_ref[...].astype(out_ref.dtype)


def tiered_gather_quant_pallas(
    cache_flat: jax.Array,
    scale_flat: jax.Array,
    idx: jax.Array,
    slot_table: jax.Array,
    w: jax.Array,
    *,
    shard_rows: int,
    interpret: bool = False,
) -> jax.Array:
    """Quantized twin of `tiered_gather_pallas`: the device cache holds
    int8/fp8 payload rows plus per-row fp32 scales; both are gathered
    through the same shard->slot indirection and dequantized in VMEM.

    Args:
      cache_flat: (cache_slots * shard_rows, m) quantized device cache.
      scale_flat: (cache_slots * shard_rows,) fp32 per-row scales.
      idx / slot_table / w / shard_rows: as in `tiered_gather_pallas`.
    """
    if shard_rows & (shard_rows - 1):
        raise ValueError("shard_rows must be a power of two")
    log2r = shard_rows.bit_length() - 1
    lead = idx.shape[:-1]
    top_k = idx.shape[-1]
    m = cache_flat.shape[-1]
    idx_flat = idx.reshape(-1, top_k).astype(jnp.int32)
    w_flat = w.reshape(-1, top_k).astype(jnp.float32)
    scale_col = scale_flat.reshape(-1, 1).astype(jnp.float32)
    n = idx_flat.shape[0]

    def _row_index(t, k, idx_sref, slot_sref):
        gid = idx_sref[t, k]
        slot = slot_sref[gid >> log2r]
        return (slot * shard_rows + (gid & (shard_rows - 1)), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n, top_k),
        in_specs=[
            pl.BlockSpec((1, top_k), lambda t, k, idx_sref, slot_sref: (t, 0)),
            pl.BlockSpec((1, m), _row_index),
            pl.BlockSpec((1, 1), _row_index),
        ],
        out_specs=pl.BlockSpec(
            (1, m), lambda t, k, idx_sref, slot_sref: (t, 0)
        ),
    )
    out = pl.pallas_call(
        _kernel_quant,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(idx_flat, slot_table.astype(jnp.int32), w_flat, cache_flat, scale_col)
    return out.reshape(*lead, m)


def tiered_gather_ref(
    cache_flat: jax.Array,
    idx: jax.Array,
    slot_table: jax.Array,
    w: jax.Array,
    *,
    shard_rows: int,
) -> jax.Array:
    """jnp reference for the indirected gather (tests / CPU fallback)."""
    log2r = shard_rows.bit_length() - 1
    slot = jnp.take(slot_table, idx >> log2r, axis=0)
    rows = jnp.take(
        cache_flat, slot * shard_rows + (idx & (shard_rows - 1)), axis=0
    )
    return jnp.einsum("...k,...km->...m", w.astype(jnp.float32), rows)


def tiered_gather_quant_ref(
    cache_flat: jax.Array,
    scale_flat: jax.Array,
    idx: jax.Array,
    slot_table: jax.Array,
    w: jax.Array,
    *,
    shard_rows: int,
) -> jax.Array:
    """jnp reference for the quantized indirected gather."""
    log2r = shard_rows.bit_length() - 1
    slot = jnp.take(slot_table, idx >> log2r, axis=0)
    cache_rows = slot * shard_rows + (idx & (shard_rows - 1))
    rows = jnp.take(cache_flat, cache_rows, axis=0).astype(jnp.float32)
    ws = w.astype(jnp.float32) * jnp.take(scale_flat, cache_rows, axis=0)
    return jnp.einsum("...k,...km->...m", ws, rows)


# the indirected cells of the lookup-plan kernel registry: the tiered
# store's device-cache gather resolves these instead of importing this
# module by name (repro.core.lookup / repro.memstore.store)
lookup.register_kernel("pallas", "tiered", tiered_gather_pallas)
lookup.register_kernel("pallas", "tiered-quant", tiered_gather_quant_pallas)
