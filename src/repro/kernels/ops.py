"""Differentiable jit'd wrappers around the Pallas kernels.

The paper's CUDA kernel emits (indices, weights, dweights/dquery) and a
PyTorch autograd wrapper consumes them.  Here the same contract is a
jax.custom_vjp pair:

  * forward: Pallas kernels (or the jnp reference when `use_pallas=False`)
  * backward:
      - d values = scatter-add of w (x) g over the touched rows (sparse:
        <= top_k rows per query),
      - d query via the analytic kernel derivative
        dw/dq = -(1 - d^2/8)^3 * (q - k)   (f(r)=max(0,1-r^2/8)^4),
        with the neighbor position k recovered from the stored index by
        nearest-image unwrapping on the torus.

On this CPU container the Pallas path runs in interpret mode (set by
`interpret=True`); on real TPUs the same code JITs to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import indexing, lattice
from repro.kernels import e8_lookup, gather_interp, ref


def _decode_index_table(spec: indexing.TorusSpec) -> None:
    """Torus points for every index — only used for small test tables."""
    return jnp.asarray(
        indexing.decode_index(np.arange(spec.num_locations), spec)
    )


def _nearest_image_delta(q: jax.Array, k_wrapped: jax.Array, K) -> jax.Array:
    """q - k for the nearest torus image of k (exact within kernel radius)."""
    Kv = jnp.asarray(K, dtype=q.dtype)
    delta = q - k_wrapped
    return delta - Kv * jnp.round(delta / Kv)


# ---------------------------------------------------------------------------
# lookup = query + gather, fused behind one custom_vjp
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def lram_lookup(
    values: jax.Array,
    q: jax.Array,
    spec: indexing.TorusSpec,
    top_k: int = lattice.DEFAULT_TOP_K,
    use_pallas: bool = True,
    interpret: bool = True,
):
    """out[t] = sum_k f(d(q_t, k)) * values[k] over the top_k nearest slots."""
    out, _ = _lookup_fwd(values, q, spec, top_k, use_pallas, interpret)
    return out


def _lookup_fwd(values, q, spec, top_k, use_pallas, interpret):
    if use_pallas:
        idx, w = e8_lookup.lram_query_pallas(
            q, spec, top_k, interpret=interpret
        )
        out = gather_interp.gather_interp_pallas(
            values, idx, w, interpret=interpret
        )
    else:
        idx, w = ref.lram_query_ref(q, spec, top_k)
        out = ref.gather_interp_ref(values, idx, w)
    return out.astype(jnp.float32), (values, q, idx, w)


def _lookup_bwd(spec, top_k, use_pallas, interpret, res, g):
    values, q, idx, w = res
    g = g.astype(jnp.float32)
    # ---- d values: sparse scatter-add (the paper's backward CUDA kernel) --
    m = values.shape[-1]
    flat_idx = idx.reshape(-1)
    flat_wg = (w[..., None] * g[..., None, :]).reshape(-1, m)
    dvalues = jnp.zeros(values.shape, jnp.float32).at[flat_idx].add(flat_wg)
    # ---- d query via analytic dw/dq --------------------------------------
    # recover neighbor positions from indices (nearest torus image)
    pts = _points_from_indices(idx, spec)  # (..., k, 8)
    delta = _nearest_image_delta(q[..., None, :], pts, spec.K)  # (...,k,8)
    d2 = jnp.sum(delta * delta, axis=-1)
    relu = jnp.maximum(0.0, 1.0 - d2 / lattice.RADIUS_SQ)
    # dw/dq = -(relu)^3 * delta ; dL/dw_k = g . values[idx_k]
    rows = jnp.take(values, idx, axis=0).astype(jnp.float32)
    dL_dw = jnp.einsum("...m,...km->...k", g, rows)
    dq = jnp.sum(
        (dL_dw * (relu**3))[..., None] * (-delta), axis=-2
    )
    return dvalues.astype(values.dtype), dq.astype(q.dtype)


def _points_from_indices(idx: jax.Array, spec: indexing.TorusSpec):
    """Invert the index bijection inside the graph (vectorised int ops)."""
    M = spec.M
    p = idx & 1
    r = idx >> 1
    half = M[7] >> 1
    j8 = jnp.mod(r, half)
    idx7 = r // half
    us = []
    for i in reversed(range(7)):
        us.append(jnp.mod(idx7, M[i]))
        idx7 = idx7 // M[i]
    u = jnp.stack(us[::-1], axis=-1)  # (..., 7)
    qpar = jnp.sum(u, axis=-1) & 1
    u8 = 2 * j8 + qpar
    full = jnp.concatenate([u, u8[..., None]], axis=-1)
    return (2 * full + p[..., None]).astype(jnp.float32)


lram_lookup.defvjp(_lookup_fwd, _lookup_bwd)


def make_interp_impl(spec: indexing.TorusSpec, top_k: int,
                     *, use_pallas: bool = True, interpret: bool = True):
    """A legacy callable `interp_impl` hook for repro.core.lram.lram_apply.

    Deprecated: the plan registry (`repro.core.lookup`) resolves
    `interp_impl="pallas"` to the same kernels with the sparse-backward
    custom VJP attached; passing this hook goes through the callable
    deprecation shim.  Kept for direct use outside lram_apply.

    Note: when plugged into lram_apply the query pipeline still runs in jnp
    (lram_apply computes idx/w itself); this hook swaps only the gather.
    Use `lram_lookup` directly for the fully-fused differentiable path.
    """

    def interp(values, idx, w):
        if use_pallas:
            return gather_interp.gather_interp_pallas(
                values, idx, w, interpret=interpret
            )
        return ref.gather_interp_ref(values, idx, w)

    return interp
