"""Bandwidth-critical value gather + interpolation (Pallas TPU).

Computes  out[t] = sum_k w[t,k] * values[idx[t,k]]  — the random-access read
the paper implements as a CUDA gather.  On TPU, random HBM access is driven
by the scalar-prefetch mechanism: the flat index array is prefetched into
SMEM *before* the kernel runs and drives the BlockSpec index_map, so each
grid step DMAs exactly one value row HBM->VMEM (the TPU analogue of the
coalesced per-warp gather).  The output block revisits the same row across
the k axis, accumulating in VMEM (TPU grids execute sequentially, so
revisiting is the standard reduction pattern).

Per-step DMA is one (1, m) row (m = 64 -> 256 B..1 KiB) — a production
deployment at billions of entries keeps the table HBM-resident and this
row-granular DMA *is* the O(1) random-access model of the paper; the row
size (not N) fixes the cost per lookup.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import lookup


def _kernel(idx_ref, w_ref, row_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    t = pl.program_id(0)
    weight = w_ref[0, k]
    out_ref[...] += weight * row_ref[...].astype(out_ref.dtype)


def gather_interp_pallas(
    values: jax.Array,
    idx: jax.Array,
    w: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """sum_k w[..., k] * values[idx[..., k]] -> (..., m).

    Non-differentiable by itself; repro.kernels.ops adds the custom_vjp
    (scatter-add for dvalues, gathered dot for dw).
    """
    lead = idx.shape[:-1]
    top_k = idx.shape[-1]
    m = values.shape[-1]
    idx_flat = idx.reshape(-1, top_k)
    w_flat = w.reshape(-1, top_k).astype(jnp.float32)
    n = idx_flat.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, top_k),
        in_specs=[
            pl.BlockSpec((1, top_k), lambda t, k, idx_sref: (t, 0)),
            pl.BlockSpec(
                (1, m), lambda t, k, idx_sref: (idx_sref[t, k], 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, m), lambda t, k, idx_sref: (t, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(idx_flat, w_flat, values)
    return out.reshape(*lead, m)


# ---------------------------------------------------------------------------
# fused dequant variant: rows move HBM->VMEM in their 1-byte form
# ---------------------------------------------------------------------------

def _kernel_quant(idx_ref, w_ref, row_ref, scale_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    # dequantize in VMEM: the DMA'd row is int8/fp8; its per-row fp32 scale
    # rides a (1, 1) block through the same index_map.  The multiply-
    # accumulate stays fp32, so only the memory traffic changes.
    weight = w_ref[0, k] * scale_ref[0, 0]
    out_ref[...] += weight * row_ref[...].astype(out_ref.dtype)


def gather_interp_quant_pallas(
    q: jax.Array,
    scale: jax.Array,
    idx: jax.Array,
    w: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """sum_k w[..., k] * scale[i] * q[i := idx[..., k]] -> (..., m).

    Same scalar-prefetch gather as `gather_interp_pallas`, but the value
    table operand is the quantized payload (int8 or float8_e4m3fn) and each
    grid step additionally DMAs the row's fp32 scale; dequantization is a
    scalar multiply fused into the VMEM accumulation.  Per-step traffic
    drops from 4*m bytes to m + 4.
    """
    lead = idx.shape[:-1]
    top_k = idx.shape[-1]
    m = q.shape[-1]
    idx_flat = idx.reshape(-1, top_k)
    w_flat = w.reshape(-1, top_k).astype(jnp.float32)
    scale_col = scale.reshape(-1, 1).astype(jnp.float32)
    n = idx_flat.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, top_k),
        in_specs=[
            pl.BlockSpec((1, top_k), lambda t, k, idx_sref: (t, 0)),
            pl.BlockSpec(
                (1, m), lambda t, k, idx_sref: (idx_sref[t, k], 0)
            ),
            pl.BlockSpec(
                (1, 1), lambda t, k, idx_sref: (idx_sref[t, k], 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, m), lambda t, k, idx_sref: (t, 0)),
    )
    out = pl.pallas_call(
        _kernel_quant,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(idx_flat, w_flat, q, scale_col)
    return out.reshape(*lead, m)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def gather_interp_quant(q, scale, idx, w, interpret=True):
    """Differentiable wrapper for the fused-dequant Pallas gather.

    Scalar-prefetch pallas_calls have no autodiff rule, and a quantized
    table is a frozen store (its training path is the tiered write-back),
    so the only live cotangent is dw — the dequantized-row dot, computed
    with a plain jnp gather in the backward.  Matches the dw contract of
    `repro.kernels.ops.lram_lookup`.
    """
    return gather_interp_quant_pallas(q, scale, idx, w, interpret=interpret)


def _quant_fwd(q, scale, idx, w, interpret):
    out = gather_interp_quant_pallas(q, scale, idx, w, interpret=interpret)
    return out, (q, scale, idx, w)


def _quant_bwd(interpret, res, g):
    q, scale, idx, w = res
    rows = jnp.take(q, idx, axis=0).astype(jnp.float32) \
        * jnp.take(scale, idx, axis=0)[..., None]
    dw = jnp.einsum("...m,...km->...k", g.astype(jnp.float32), rows)
    zero = (np.zeros(q.shape, jax.dtypes.float0)
            if not jnp.issubdtype(q.dtype, jnp.inexact)
            else jnp.zeros(q.shape, q.dtype))
    return (
        zero,
        jnp.zeros(scale.shape, scale.dtype),
        np.zeros(idx.shape, jax.dtypes.float0),
        dw.astype(w.dtype),
    )


gather_interp_quant.defvjp(_quant_fwd, _quant_bwd)


# ---------------------------------------------------------------------------
# differentiable fp32 wrapper (the "pallas" kernel cell of the plan matrix)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def gather_interp_vjp(values, idx, w, interpret=True):
    """Differentiable wrapper for the fp32 Pallas gather.

    Scalar-prefetch pallas_calls have no autodiff rule, so the backward is
    supplied here with the same contract as `repro.kernels.ops.
    lram_lookup`: d values is the paper's sparse scatter-add over the
    touched rows, d w the gathered-row dot.  This is what lets the dense
    and sharded placements run the Pallas kernel under `jax.grad`.
    """
    return gather_interp_pallas(values, idx, w, interpret=interpret)


def _vjp_fwd(values, idx, w, interpret):
    out = gather_interp_pallas(values, idx, w, interpret=interpret)
    return out, (values, idx, w)


def _vjp_bwd(interpret, res, g):
    values, idx, w = res
    g = g.astype(jnp.float32)
    m = values.shape[-1]
    flat_idx = idx.reshape(-1)
    flat_wg = (w.astype(jnp.float32)[..., None]
               * g[..., None, :]).reshape(-1, m)
    dvalues = jnp.zeros(values.shape, jnp.float32).at[flat_idx].add(flat_wg)
    rows = jnp.take(values, idx, axis=0).astype(jnp.float32)
    dw = jnp.einsum("...m,...km->...k", g, rows)
    return (
        dvalues.astype(values.dtype),
        np.zeros(idx.shape, dtype=jax.dtypes.float0),
        dw.astype(w.dtype),
    )


gather_interp_vjp.defvjp(_vjp_fwd, _vjp_bwd)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# the "pallas" kernel axis of the lookup-plan registry
# (repro.core.lookup): interpret mode is chosen per backend at call time
lookup.register_kernel(
    "pallas", "fp32",
    lambda values, idx, w: gather_interp_vjp(values, idx, w, _interpret()),
)
lookup.register_kernel(
    "pallas", "quant",
    lambda table, idx, w: gather_interp_quant(
        table.q, table.scale, idx, w, _interpret()
    ),
)
