"""Bandwidth-critical value gather + interpolation (Pallas TPU).

Computes  out[t] = sum_k w[t,k] * values[idx[t,k]]  — the random-access read
the paper implements as a CUDA gather.  On TPU, random HBM access is driven
by the scalar-prefetch mechanism: the flat index array is prefetched into
SMEM *before* the kernel runs and drives the BlockSpec index_map, so each
grid step DMAs exactly one value row HBM->VMEM (the TPU analogue of the
coalesced per-warp gather).  The output block revisits the same row across
the k axis, accumulating in VMEM (TPU grids execute sequentially, so
revisiting is the standard reduction pattern).

Per-step DMA is one (1, m) row (m = 64 -> 256 B..1 KiB) — a production
deployment at billions of entries keeps the table HBM-resident and this
row-granular DMA *is* the O(1) random-access model of the paper; the row
size (not N) fixes the cost per lookup.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, w_ref, row_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    t = pl.program_id(0)
    weight = w_ref[0, k]
    out_ref[...] += weight * row_ref[...].astype(out_ref.dtype)


def gather_interp_pallas(
    values: jax.Array,
    idx: jax.Array,
    w: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """sum_k w[..., k] * values[idx[..., k]] -> (..., m).

    Non-differentiable by itself; repro.kernels.ops adds the custom_vjp
    (scatter-add for dvalues, gathered dot for dw).
    """
    lead = idx.shape[:-1]
    top_k = idx.shape[-1]
    m = values.shape[-1]
    idx_flat = idx.reshape(-1, top_k)
    w_flat = w.reshape(-1, top_k).astype(jnp.float32)
    n = idx_flat.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, top_k),
        in_specs=[
            pl.BlockSpec((1, top_k), lambda t, k, idx_sref: (t, 0)),
            pl.BlockSpec(
                (1, m), lambda t, k, idx_sref: (idx_sref[t, k], 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, m), lambda t, k, idx_sref: (t, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(idx_flat, w_flat, values)
    return out.reshape(*lead, m)
