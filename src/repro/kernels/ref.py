"""Pure-jnp oracles for the Pallas kernels.

These are *the* reference semantics: every kernel test sweeps shapes/dtypes
and asserts allclose against these functions, which are themselves built on
the exhaustively-tested repro.core.lattice pipeline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import indexing, lattice, lookup, lram


def lram_query_ref(
    q: jax.Array, spec: indexing.TorusSpec, top_k: int = lattice.DEFAULT_TOP_K
) -> tuple[jax.Array, jax.Array]:
    """Top-k (index, weight) pairs — same contract as lram_query_pallas."""
    return lram.indices_and_weights(q.astype(jnp.float32), spec, top_k)


def gather_interp_ref(
    values: jax.Array, idx: jax.Array, w: jax.Array
) -> jax.Array:
    """sum_k w_k * values[idx_k] — same contract as gather_interp_pallas."""
    return lram.gather_interp(values, idx, w.astype(jnp.float32))


def lookup_ref(
    values: jax.Array,
    q: jax.Array,
    spec: indexing.TorusSpec,
    top_k: int = lattice.DEFAULT_TOP_K,
) -> jax.Array:
    idx, w = lram_query_ref(q, spec, top_k)
    return gather_interp_ref(values, idx, w)


def _gather_interp_quant_ref(table, idx, w):
    from repro import quant

    return quant.gather_interp_quant(table, idx, w)


# the "reference" kernel axis of the lookup-plan registry: plain jnp
# gathers for fp32 tables and QuantizedTables (repro.core.lookup)
lookup.register_kernel("reference", "fp32", gather_interp_ref)
lookup.register_kernel("reference", "quant", _gather_interp_quant_ref)
