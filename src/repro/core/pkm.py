"""Product-Key Memory (Lample et al. 2019) — the paper's main baseline.

O(sqrt(N)) lookup: keys form a Cartesian product of two codebooks of
sqrt(N) half-keys; per head, score both halves, take top-k in each, combine
the k*k Cartesian candidates and re-select top-k; softmax the scores and
gather value rows.  Configured as in the paper's comparison: 8 heads,
N = 2**16, value dim 512, key dim 64, batchnorm on queries.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import nn


@dataclasses.dataclass(frozen=True)
class PKMConfig:
    n_keys: int = 256          # memory locations = n_keys**2 (2**16)
    heads: int = 8
    key_dim: int = 64          # per-half query/key dim = key_dim/2... see init
    value_dim: int = 512
    top_k: int = 32
    query_norm: str = "batch"
    value_init_scale: float = 0.02

    @property
    def num_locations(self) -> int:
        return self.n_keys**2

    @property
    def half_dim(self) -> int:
        return self.key_dim // 2

    @property
    def num_params(self) -> int:
        return (
            self.num_locations * self.value_dim
            + 2 * self.heads * self.n_keys * self.half_dim
        )


def pkm_init(key, in_dim: int, cfg: PKMConfig, *, dtype=jnp.float32):
    kq, k1, k2, kv = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "query": nn.dense_init(kq, in_dim, cfg.heads * cfg.key_dim, dtype=dtype),
        "subkeys1": nn.fan_in_init()(k1, (cfg.heads, cfg.n_keys, cfg.half_dim), dtype),
        "subkeys2": nn.fan_in_init()(k2, (cfg.heads, cfg.n_keys, cfg.half_dim), dtype),
        "values": nn.truncated_normal_init(cfg.value_init_scale)(
            kv, (cfg.num_locations, cfg.value_dim), dtype
        ),
    }
    state: dict[str, Any] = {}
    if cfg.query_norm == "batch":
        params["qnorm"], state["qnorm"] = nn.batchnorm_init(
            cfg.heads * cfg.key_dim, dtype=dtype
        )
    return params, state


def pkm_apply(params, state, x, cfg: PKMConfig, *, train: bool = False,
              return_access: bool = False):
    """x: (..., in_dim) -> (..., value_dim)."""
    lead = x.shape[:-1]
    q = nn.dense(params["query"], x)  # (..., heads*key_dim)
    new_state = dict(state)
    if cfg.query_norm == "batch":
        q, new_state["qnorm"] = nn.batchnorm(
            params["qnorm"], state["qnorm"], q, train=train
        )
    q = q.reshape(*lead, cfg.heads, 2, cfg.half_dim).astype(jnp.float32)
    q1, q2 = q[..., 0, :], q[..., 1, :]  # (..., heads, half_dim)

    s1 = jnp.einsum("...hd,hnd->...hn", q1, params["subkeys1"].astype(jnp.float32))
    s2 = jnp.einsum("...hd,hnd->...hn", q2, params["subkeys2"].astype(jnp.float32))
    t1, i1 = jax.lax.top_k(s1, cfg.top_k)  # (..., heads, k)
    t2, i2 = jax.lax.top_k(s2, cfg.top_k)
    # Cartesian combination: scores (..., heads, k, k)
    comb = t1[..., :, None] + t2[..., None, :]
    flat = comb.reshape(*comb.shape[:-2], cfg.top_k * cfg.top_k)
    scores, sel = jax.lax.top_k(flat, cfg.top_k)  # (..., heads, k)
    r1 = jnp.take_along_axis(i1, sel // cfg.top_k, axis=-1)
    r2 = jnp.take_along_axis(i2, sel % cfg.top_k, axis=-1)
    idx = r1 * cfg.n_keys + r2  # (..., heads, k) flat memory indices
    w = jax.nn.softmax(scores, axis=-1)
    rows = jnp.take(params["values"], idx, axis=0).astype(w.dtype)
    out = jnp.einsum("...hk,...hkm->...m", w, rows)  # sum over heads too
    out = out.astype(x.dtype)
    if return_access:
        return out, new_state, (idx, w)
    return out, new_state


def flop_count(in_dim: int, tokens: int, cfg: PKMConfig) -> int:
    """Paper Table 3: 2*w*sqrt(N) + w^2 + O(w) per token."""
    per_tok = (
        2 * in_dim * cfg.heads * cfg.key_dim  # query proj
        + 2 * cfg.heads * 2 * cfg.n_keys * cfg.half_dim  # half scores
        + cfg.heads * cfg.top_k * cfg.value_dim * 2  # gather+reduce
    )
    return tokens * per_tok
