"""Torus-parameterising activation (paper §2.3).

Embeds the torus T_K in C^n as a product of unit circles: the query point is
read off the *arguments* of the complex entries, and the lookup output is
scaled by the reciprocal sum of reciprocal magnitudes,

    theta(z_1..z_n) = (sum_i 1/|z_i|)^{-1} * phi(K_i/(2pi) * arg z_i, ...)

which makes theta Lipschitz (no discontinuity at z=0: the scale vanishes
there) and positively 1-homogeneous: theta(lambda z) = lambda theta(z) for
lambda >= 0 — the network controls output magnitude through query magnitude.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_TWO_PI = 2.0 * np.pi
_SAFE_EPS = 1e-20


def torus_map(x: jnp.ndarray, K) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Map real inputs (..., 2n) to torus coords (..., n) + scale (..., 1).

    The first n features are the real parts, the last n the imaginary parts
    (a layout that keeps each half contiguous for sharding).  The scale is
    (sum_i 1/|z_i|)^{-1}, exactly the paper's formula.  Where |z_i| ~ 0 the
    angle is undefined; a double-`where` keeps gradients finite (the scale
    factor sends the output itself to zero there, preserving continuity).
    """
    n = x.shape[-1] // 2
    re, im = x[..., :n], x[..., n:]
    # XLA CPU's atan2 returns NaN for denormal arguments; flushing them to
    # zero is exact at float32 angle resolution.
    re = jnp.where(jnp.abs(re) < 1e-30, 0.0, re)
    im = jnp.where(jnp.abs(im) < 1e-30, 0.0, im)
    mag_sq = re * re + im * im
    safe = mag_sq > _SAFE_EPS
    re_s = jnp.where(safe, re, 1.0)
    im_s = jnp.where(safe, im, 0.0)
    theta = jnp.arctan2(im_s, re_s)  # (-pi, pi]
    K = jnp.asarray(K, dtype=x.dtype)
    q = jnp.mod(theta / _TWO_PI, 1.0) * K  # [0, K)
    mag = jnp.sqrt(jnp.where(safe, mag_sq, 1.0))
    inv = jnp.where(safe, 1.0 / mag, 1.0 / jnp.sqrt(_SAFE_EPS))
    scale = 1.0 / jnp.sum(inv, axis=-1, keepdims=True)
    return q, scale
