"""LRAM: the lattice-based differentiable random-access memory layer.

Composition (paper §2.3, §3.1):

    x (..., 2*h*8) --per-head query norm--> torus_map --> q (..., h, 8)
      --E8 neighbor enumeration--> top-32 (index, weight) pairs
      --gather from shared value table (N, m), weighted sum, scale-->
    y (..., h*m)

plus the memory-augmented FFN block that replaces a transformer FFN:
dense(w -> w) . LRAM(w -> 4w, (n,m,h)=(8,64,w/16)) . dense(4w -> w).

The lookup is O(1) in N: per query it touches 232 candidate rows of a fixed
table (one 8x232 MXU matmul) and gathers top_k=32 value rows.  Gradients are
input-dependent-sparse: dL/dvalues has at most 32*h nonzero rows per token
(autodiff of the gather produces exactly the scatter-add the paper's CUDA
backward implements).

Implementation selection is a **plan** over three orthogonal axes
(`repro.core.lookup`): placement (`LRAMConfig.interp_impl` — dense |
tiered | sharded | sharded-tiered, with "reference"/"pallas" as dense
aliases), storage (`LRAMConfig.table_quant` — fp32 | int8 | fp8 rows with
per-row scales, `repro.quant`), and kernel (`LRAMConfig.lookup_kernel` —
jnp reference or the Pallas scalar-prefetch kernels).  The plan is
resolved once at `lram_init`/trace time; it builds the value table
(`params["values"]` — a dense array, `QuantizedTable`,
`TieredValueStore`, or `ShardedTieredStore`) and owns the gather+interp
step with its autodiff contract.  `lram_apply`'s `interp_impl` argument
overrides the config's placement per call (an impl name string; the legacy
callable-hook protocol was removed — register a placement backend instead).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import nn
from repro.core import indexing, lattice, lookup, overlay, torus


@dataclasses.dataclass(frozen=True)
class LRAMConfig:
    log2_locations: int = 18  # N = 2**18 == paper's LRAM-small
    # explicit wrap lengths (indexing.TorusSpec) — set by memctl.grow:
    # grown configs carry the index-preserving K_0-enlarged torus instead
    # of the near-cubic choose_torus default.  None = choose_torus.
    torus: Any = None
    m: int = 64               # value dim per head (paper: 64)
    heads: int = 32           # h; layer input dim = 16*h, output = m*h
    top_k: int = 32           # paper §2.6: top-32 carries >=99.5% of mass
    query_norm: str = "batch"  # batch | rms | none  (paper: batchnorm)
    value_init_scale: float = 0.02
    table_dtype: str = "float32"
    # --- the lookup plan's three axes (repro.core.lookup) ---
    interp_impl: str = "reference"  # placement: reference/pallas (dense) |
    #                                 tiered | sharded | sharded-tiered
    tiered: Any = None              # memstore.TieredSpec for tiered placements
    table_quant: str = "none"       # storage: none | int8 | fp8
    lookup_kernel: str = "auto"     # kernel: auto | reference | pallas
    model_shards: int = 0           # sharded-tiered row-range owners
    #                                 (0 = ambient mesh's model-axis size)

    def __post_init__(self):
        if self.table_quant not in ("none", "int8", "fp8"):
            raise ValueError(
                f"table_quant must be none|int8|fp8, got {self.table_quant!r}"
            )
        if self.torus is not None \
                and self.torus.num_locations != 2**self.log2_locations:
            raise ValueError(
                f"torus has {self.torus.num_locations} locations but "
                f"log2_locations={self.log2_locations}"
            )

    @property
    def torus_spec(self) -> indexing.TorusSpec:
        if self.torus is not None:
            return self.torus
        return indexing.choose_torus(self.log2_locations)

    @property
    def num_locations(self) -> int:
        return 2**self.log2_locations

    @property
    def in_dim(self) -> int:
        return 2 * lattice.DIM * self.heads

    @property
    def out_dim(self) -> int:
        return self.m * self.heads

    @property
    def num_params(self) -> int:
        return self.num_locations * self.m

    @property
    def table_bytes_per_entry(self) -> int:
        """Storage bytes per table row (payload + per-row scale if quantized)."""
        from repro import quant

        if self.table_quant == "none":
            return self.m * jnp.dtype(self.table_dtype).itemsize
        return quant.bytes_per_entry(self.m, self.table_quant)


# ---------------------------------------------------------------------------
# Lookup primitives (reference path; the plan registry swaps the rest)
# ---------------------------------------------------------------------------

def indices_and_weights(
    q: jax.Array, spec: indexing.TorusSpec, top_k: int
) -> tuple[jax.Array, jax.Array]:
    """Top-k (lattice index, kernel weight) pairs for queries q (..., 8).

    Two equivalent selection strategies (tests assert identical results):

    * no mesh (host/tests): jax.lax.top_k — fastest single-device.
    * under a mesh: top_k unrolled masked-argmax passes (mirroring the
      Pallas kernel).  XLA's sort-based top_k does not partition on
      non-sort dims and all-gathered the full 232-candidate tensor
      (87 GiB/step at pod scale — EXPERIMENTS.md §Perf cell 3);
      argmax/where/sum are trivially shard-local, and indices for all 232
      candidates are computed up front and selected by an exact integer
      one-hot reduction — no sorts, no gathers."""
    from repro.distributed import context as _ctx

    nbrs, w = lattice.neighbors_and_weights(q)  # (...,232,8), (...,232)
    if _ctx.get_mesh() is None:
        # host path: sort-based top_k is fastest on a single device
        w_top, sel = jax.lax.top_k(w, top_k)
        nb_top = jnp.take_along_axis(
            nbrs, sel[..., None].astype(jnp.int32), axis=-2
        )
        return indexing.encode_points(nb_top, spec), w_top
    idx_all = indexing.encode_points(nbrs, spec)  # (..., 232) int32
    iota = jax.lax.broadcasted_iota(jnp.int32, w.shape, w.ndim - 1)
    scores = w
    idxs, ws = [], []
    for _ in range(top_k):
        m = jnp.max(scores, axis=-1)
        am = jnp.argmax(scores, axis=-1)
        hit = iota == am[..., None]
        idxs.append(jnp.sum(jnp.where(hit, idx_all, 0), axis=-1))
        ws.append(m)
        scores = jnp.where(hit, -1.0, scores)
    return jnp.stack(idxs, axis=-1), jnp.stack(ws, axis=-1)


def gather_interp(values: jax.Array, idx: jax.Array, w: jax.Array) -> jax.Array:
    """sum_k w_k * values[idx_k]  -> (..., m).  Reference implementation."""
    rows = jnp.take(values, idx, axis=0).astype(w.dtype)  # (..., k, m)
    return jnp.einsum("...k,...km->...m", w, rows)


# ---------------------------------------------------------------------------
# The layer
# ---------------------------------------------------------------------------

def lram_init(key, cfg: LRAMConfig, *, dtype=jnp.float32):
    """Returns (params, state). State holds batchnorm running stats.

    The value table is built by the resolved lookup plan
    (`repro.core.lookup`): every placement starts from the *same* RNG
    draw, so a tiered/sharded/quantized layer is numerically identical to
    its dense fp32 twin at init up to storage rounding."""
    kv, _ = jax.random.split(key)
    plan = lookup.resolve(cfg)
    table_dtype = jnp.dtype(cfg.table_dtype)
    values = plan.build_table(
        nn.truncated_normal_init(cfg.value_init_scale)(
            kv, (cfg.num_locations, cfg.m), table_dtype
        )
    )
    params: dict[str, Any] = {"values": values}
    state: dict[str, Any] = {}
    if cfg.query_norm == "batch":
        params["qnorm"], state["qnorm"] = nn.batchnorm_init(
            2 * lattice.DIM, dtype=dtype
        )
    elif cfg.query_norm == "rms":
        params["qnorm"] = nn.rmsnorm_init(2 * lattice.DIM, dtype=dtype)
    return params, state


def lram_apply(
    params,
    state,
    x: jax.Array,
    cfg: LRAMConfig,
    *,
    train: bool = False,
    interp_impl: str | None = None,
    return_access: bool = False,
):
    """Apply the memory layer.

    Args:
      x: (..., 2*8*heads) inputs.
      interp_impl: optional placement override for the gather+interpolate
        step — an impl name ("reference" | "pallas" | "tiered" | "sharded"
        | "sharded-tiered"); defaults to cfg.interp_impl.  Resolution goes
        through `repro.core.lookup.resolve`, which raises
        `LookupPlanError` for unsupported cells (callables included: the
        legacy hook protocol was removed).
      return_access: additionally return (indices, weights) — used by the
        memory-utilisation analysis (paper Table 5).

    Returns:
      (y, new_state[, access]) with y: (..., heads*m).
    """
    if x.shape[-1] != cfg.in_dim:
        raise ValueError(f"LRAM expects {cfg.in_dim} features, got {x.shape}")
    plan = lookup.resolve(cfg, interp_impl)
    lead = x.shape[:-1]
    xh = x.reshape(*lead, cfg.heads, 2 * lattice.DIM)
    # heads ride the tensor-parallel axis (table shared/replicated): the
    # whole query->decode->gather pipeline then stays shard-local
    from repro.distributed import context as _ctx
    xh = _ctx.constrain(
        xh, *( (_ctx.batch_axes(),) + (None,) * (len(lead) - 1)
               + ("model", None) )
    )
    new_state = dict(state)
    if cfg.query_norm == "batch":
        xh, new_state["qnorm"] = nn.batchnorm(
            params["qnorm"], state["qnorm"], xh, train=train
        )
    elif cfg.query_norm == "rms":
        xh = nn.rmsnorm(params["qnorm"], xh)

    spec = cfg.torus_spec
    q, scale = torus.torus_map(xh.astype(jnp.float32), spec.K)
    idx, w = indices_and_weights(q, spec, cfg.top_k)
    out = plan.interp(params["values"], idx, w)
    # per-tenant overlay (serve engine): correct rows the tenant has
    # overwritten, and record the access for the decode-step writeback.
    # Trace-time only — `current()` is None outside an engine overlay
    # context, and jit never re-runs this Python on cached calls.
    octx = overlay.current()
    if octx is not None:
        out = octx.apply(idx, w, out)
    # (..., heads, m)
    out = out * scale
    if octx is not None:
        octx.record(idx, w, out)
    y = out.reshape(*lead, cfg.out_dim).astype(x.dtype)
    if return_access:
        return y, new_state, (idx, w)
    return y, new_state


# ---------------------------------------------------------------------------
# Memory-augmented FFN block (paper §3.1)
# ---------------------------------------------------------------------------

def memffn_config(width: int, log2_locations: int, **kw) -> LRAMConfig:
    """The paper's block shape: (n, m, h) = (8, 64, w/16)."""
    if width % 16 != 0:
        raise ValueError("width must be divisible by 16")
    return LRAMConfig(
        log2_locations=log2_locations, m=64, heads=width // 16, **kw
    )


def memffn_init(key, width: int, cfg: LRAMConfig, *, dtype=jnp.float32):
    if cfg.in_dim != width or cfg.out_dim != 4 * width:
        raise ValueError("cfg does not match the paper block shape")
    # NOTE: earlier revisions reused k1 for both lram_init and wi (k2 was
    # split but never consumed), correlating the memory table with the
    # input projection.  Seeding wi from k2 decorrelates them — an
    # intentional init-behaviour change: checkpoints are unaffected, but
    # fresh inits of this block differ from pre-fix runs.
    k1, k2, k3 = jax.random.split(key, 3)
    lram_params, lram_state = lram_init(k1, cfg, dtype=dtype)
    params = {
        "wi": nn.dense_init(k2, width, width, dtype=dtype),
        "lram": lram_params,
        "wo": nn.dense_init(k3, 4 * width, width, dtype=dtype),
    }
    return params, {"lram": lram_state}


def memffn_apply(
    params,
    state,
    x: jax.Array,
    cfg: LRAMConfig,
    *,
    train: bool = False,
    interp_impl: str | None = None,
):
    h = nn.dense(params["wi"], x)
    h, lram_state = lram_apply(
        params["lram"], state["lram"], h, cfg, train=train,
        interp_impl=interp_impl,
    )
    y = nn.dense(params["wo"], h)
    return y, {"lram": lram_state}


def flop_count(width: int, tokens: int) -> int:
    """Paper Table 3: ~(5/4)*r*w^2 MACs/token with r=4 — independent of N."""
    dense_flops = 2 * tokens * (width * width + 4 * width * width)
    lookup_flops = 2 * tokens * (width // 16) * (
        8 * lattice.NUM_CANDIDATES  # distance matmul
        + lattice.DEFAULT_TOP_K * 64  # interpolation
    )
    return dense_flops + lookup_flops
