"""Trace-time per-tenant overlay hook for the LRAM lookup.

The serve engine gives every decode slot a *fixed-shape* overlay pack —
the tenant's sparse copy-on-write rows resolved against the shared base
table (`repro.serving.overlay.OverlayManager`):

  * ``ids``    (L, B, C) int32  — overlay row ids per lram layer / slot,
    ``-1`` = empty (lattice row ids are always >= 0, so a sentinel can
    never match a real lookup index).
  * ``deltas`` (L, B, C, m) fp32 — ``dequant(overlay_row) - base_row``
    per packed id, i.e. exactly what the lookup result is missing when it
    gathered the base row instead of the tenant's row.

`lram_apply` consults :func:`current` between its gather and its scale:
when a context is active it adds ``Σ_k w_k · delta[idx_k]`` (an exact
overlay-before-base read, linearly composed), and optionally records the
post-scale per-head output so the engine can write the step back into the
tenant's overlay.  An all-empty pack contributes exactly ``0.0``, so an
engine with overlays enabled but no tenant attached is bit-identical to
the overlay-free engine.

The context is activated *inside* the engine's jitted step functions —
``jax.jit`` runs the wrapped Python once per trace, so the module-level
state below is consulted only at trace time, and the packs (traced jit
arguments) are baked into the compiled graph as inputs.  Attach/detach
then only mutates the host-side pack arrays: zero recompilation across
admit/retire.  Layers consume pack slices in `transformer.layer_plan`
order via a plain Python counter, which is deterministic per trace.
"""

from __future__ import annotations

import contextlib

import jax.numpy as jnp

_ACTIVE: "OverlayContext | None" = None


def current() -> "OverlayContext | None":
    """The active overlay context (None outside an `activate` block)."""
    return _ACTIVE


def delta_correction(idx, w, ids, deltas):
    """``Σ_k w_k · delta[idx_k]`` with the delta rows gathered from a
    fixed-shape pack: exact-integer match of each lookup index against the
    pack's ids (no match -> an all-zero delta row).

    idx/w: (B, *lead, H, K); ids: (B, C); deltas: (B, C, m).
    Returns (B, *lead, H, m), fp32.
    """
    bcast = (ids.shape[0],) + (1,) * (idx.ndim - 1) + (ids.shape[-1],)
    hit = idx[..., None] == ids.reshape(bcast)          # (B, ..., K, C)
    rows = jnp.einsum(
        "b...c,bcm->b...m", hit.astype(deltas.dtype), deltas
    )                                                   # (B, ..., K, m)
    return jnp.einsum("...k,...km->...m", w.astype(rows.dtype), rows)


class OverlayContext:
    """One trace's overlay state: packs + the layer-consumption counter."""

    def __init__(self, ids, deltas, *, collect: bool = False):
        ids = jnp.asarray(ids)
        deltas = jnp.asarray(deltas)
        if ids.ndim != 3 or deltas.ndim != 4 \
                or ids.shape != deltas.shape[:3]:
            raise ValueError(
                f"overlay packs must be ids (L, B, C) and deltas "
                f"(L, B, C, m); got {ids.shape} / {deltas.shape}"
            )
        self.ids = ids
        self.deltas = deltas
        self.collect = collect
        self._layer = 0
        self._accesses: list[tuple] = []

    @property
    def num_layers(self) -> int:
        return int(self.ids.shape[0])

    def apply(self, idx, w, out):
        """Correct one lram layer's interpolation output (pre-scale),
        consuming the next pack slice in trace order."""
        layer = self._layer
        if layer >= self.num_layers:
            raise RuntimeError(
                f"overlay packs cover {self.num_layers} lram layer(s) but "
                f"the model traced lookup #{layer + 1} — the engine's "
                f"layer count is stale"
            )
        self._layer += 1
        return out + delta_correction(
            idx, w, self.ids[layer], self.deltas[layer]
        )

    def record(self, idx, w, y):
        """Collect one layer's (indices, weights, post-scale per-head
        output) for the engine's decode-step writeback."""
        if self.collect:
            self._accesses.append((idx, w, y))

    def stacked(self):
        """The collected accesses stacked with a leading layer axis:
        (idx (L, ...), w (L, ...), y (L, ...))."""
        if len(self._accesses) != self.num_layers:
            raise RuntimeError(
                f"collected {len(self._accesses)} lram accesses for "
                f"{self.num_layers} overlay layer(s)"
            )
        return tuple(
            jnp.stack([a[i] for a in self._accesses])
            for i in range(3)
        )


@contextlib.contextmanager
def activate(ids, deltas, *, collect: bool = False):
    """Activate an overlay context for the duration of one model trace.

    Must wrap the model call *inside* the jitted function, so the packs
    are traced arguments and the context only steers tracing."""
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("overlay contexts do not nest")
    ctx = OverlayContext(ids, deltas, collect=collect)
    _ACTIVE = ctx
    try:
        yield ctx
    finally:
        _ACTIVE = None
