"""Composable lookup-backend registry: placement × storage × kernel.

The paper's O(1) lookup has one semantic — ``out = Σₖ wₖ · values[idxₖ]`` —
but many deployment shapes.  Historically each shape was a hand-wired
implementation (an isinstance/string/callable ladder in ``core/lram``), so
the combinations that actually reach "billions of entries" (sharded AND
tiered, sharded AND pallas) were structurally impossible.  This module
replaces that ladder with a **plan**: three orthogonal axes resolved once
at config/init time into a :class:`LookupPlan` that owns table
construction, gather+interp (with its autodiff contract), checkpoint
layout, and capability flags.

Axes:

* **placement** — where the table lives:
  ``dense`` (one device array) | ``tiered`` (host shards + device hot
  cache) | ``sharded`` (rows sharded over the ``model`` mesh axis) |
  ``sharded-tiered`` (each model shard owns a host-offloaded row range
  with its own device hot cache).
* **storage** — how a row is stored: ``fp32`` | ``int8`` | ``fp8``
  (1-byte payload + per-row fp32 scales, ``repro.quant``).
* **kernel** — how the gather executes: ``reference`` (jnp take+einsum)
  | ``pallas`` (scalar-prefetch TPU kernels, interpret mode on CPU).

Backends self-register: ``repro.kernels.ref`` / ``repro.kernels.
gather_interp`` / ``repro.kernels.tiered_gather`` register gather kernels,
``repro.memstore.interp`` registers the ``tiered`` placement, and
``repro.distributed.sharded_lram`` registers ``sharded`` and
``sharded-tiered``.  :func:`resolve` lazy-imports the provider module for
whatever cell a config names, so importing ``repro.core`` stays cheap.

Unsupported cells raise :class:`LookupPlanError` **at resolve time** —
misconfiguration fails while building the layer, not deep inside a jitted
apply.  (The legacy callable ``interp_impl`` hook protocol is gone:
callables bypass the plan's capability flags and cannot compose with
tiering/quantization/growth — register a placement backend instead.)

Beyond the gather itself, the plan carries the capabilities the rest of
the system keys on: the serve engine reads ``supports_prefetch``, the
trainer reads ``table_update``, the checkpoint manager reads
``checkpoint_layout``, the GSPMD partitioner reads ``table_rows_axis``
(`repro.distributed.sharding`), and the memory lifecycle manager
(`repro.memctl`) reads ``supports_growth`` / ``row_stats`` /
``build_empty`` for online capacity growth and live plan-to-plan
migration.
"""

from __future__ import annotations

import dataclasses
import functools
import importlib
from typing import Any, Callable

PLACEMENTS = ("dense", "tiered", "sharded", "sharded-tiered")
STORAGES = ("fp32", "int8", "fp8")
KERNELS = ("reference", "pallas")

# interp_impl string -> placement (legacy names kept as aliases)
IMPL_PLACEMENT = {
    "reference": "dense",
    "dense": "dense",
    "pallas": "dense",
    "tiered": "tiered",
    "sharded": "sharded",
    "sharded-tiered": "sharded-tiered",
}


class LookupPlanError(ValueError):
    """A (placement, storage, kernel) cell that cannot be built — raised
    when the plan is resolved, with the offending cell in the message."""

    def __init__(self, placement, storage, kernel, reason: str):
        self.cell = (placement, storage, kernel)
        super().__init__(
            f"lookup plan ({placement} × {storage} × {kernel}): {reason}"
        )


@dataclasses.dataclass(frozen=True)
class LookupPlan:
    """A resolved lookup backend: one cell of placement × storage × kernel.

    ``build_table(dense_values)`` turns the init-time fp32 draw into the
    table object that sits at ``params["values"]`` (dense array,
    ``QuantizedTable``, ``TieredValueStore``, ``ShardedTieredStore``);
    every placement starts from the *same* draw, so all plans of one
    config are numerically equivalent at init up to storage rounding.

    ``interp(values, idx, w)`` is the gather+interpolate step, carrying
    the backend's autodiff contract (see ``table_update``).

    Capability flags replace isinstance probing everywhere else:

    * ``supports_prefetch`` — the table exposes ``prefetch_last()`` /
      ``warm()`` handles (serve engine per-tick prefetch).
    * ``table_update`` — how the value table trains: ``autodiff`` (dense
      dL/dvalues via the custom-VJP scatter-add), ``writeback`` (sparse
      SGD applied by the store itself), or ``frozen`` (quantized dense
      tables own no update rule).
    * ``checkpoint_layout`` — ``dense`` (one array leaf) or ``shards``
      (streamed ``shard_NNNNNN.npy`` files, ``repro.checkpoint``).
    * ``requires_mesh`` — the interp shard_maps over the ambient mesh.
    * ``supports_growth`` — `repro.memctl.grow` can enlarge this table
      live (append-only K_0 torus growth; mesh-sharded dense tables
      cannot grow without a relaunch).
    * ``row_stats`` — the table tracks per-shard access counts
      (`row_stats()` on the store), which `repro.memctl.telemetry`
      aggregates into utilisation reports.
    * ``table_rows_axis`` — the mesh axis the table's leading (row) axis
      shards over (``None`` = replicate); `distributed.sharding` emits
      the memory table's pspec from this instead of a path regex.
    * ``build_empty`` — zero-filled table of this plan's layout (store
      placements only): the migration target `repro.memctl.migrate`
      streams shards into.
    * ``supports_overlay`` — the serve engine may fuse a per-tenant
      copy-on-write row overlay (`repro.serving.overlay`) into this
      plan's lookup: overlay rows are stored in the *same* storage kind
      as the base table and resolved host-side into per-slot delta packs
      (`repro.core.overlay`), so the device graph never changes shape
      across attach/detach.  Requires host-readable base rows
      (:func:`read_rows_fp32`); the mesh-sharded dense placement keeps
      this off.
    """

    placement: str
    storage: str
    kernel: str
    build_table: Callable[[Any], Any]
    interp: Callable[[Any, Any, Any], Any]
    supports_prefetch: bool = False
    table_update: str = "autodiff"   # autodiff | writeback | frozen
    checkpoint_layout: str = "dense"  # dense | shards
    requires_mesh: bool = False
    supports_growth: bool = False
    row_stats: bool = False
    table_rows_axis: str | None = None
    build_empty: Callable[[], Any] | None = None
    supports_overlay: bool = False

    @property
    def cell(self) -> tuple[str, str, str]:
        return (self.placement, self.storage, self.kernel)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"LookupPlan({self.placement} × {self.storage} × "
                f"{self.kernel}, update={self.table_update})")


# ---------------------------------------------------------------------------
# registries (populated by provider modules at import)
# ---------------------------------------------------------------------------

# placement -> factory(cfg, storage, kernel) -> LookupPlan
_PLACEMENT_FACTORIES: dict[str, Callable] = {}
_PLACEMENT_PROVIDERS = {
    "dense": "repro.core.lookup",            # registered below
    "tiered": "repro.memstore.interp",
    "sharded": "repro.distributed.sharded_lram",
    "sharded-tiered": "repro.distributed.sharded_lram",
}

# (kernel, storage_class) -> gather callable; the storage_class names a
# calling convention, not a dtype: "fp32" (values, idx, w),
# "quant" (QuantizedTable, idx, w), "tiered[-quant]" (cache-indirected,
# see repro.kernels.tiered_gather)
_KERNEL_IMPLS: dict[tuple[str, str], Callable] = {}
_KERNEL_PROVIDERS = {
    ("reference", "fp32"): "repro.kernels.ref",
    ("reference", "quant"): "repro.kernels.ref",
    ("pallas", "fp32"): "repro.kernels.gather_interp",
    ("pallas", "quant"): "repro.kernels.gather_interp",
    ("pallas", "tiered"): "repro.kernels.tiered_gather",
    ("pallas", "tiered-quant"): "repro.kernels.tiered_gather",
}

# store classes that ride params as leafless pytree nodes (prefetch /
# write-back / shard-streaming checkpoint handles)
_STORE_TYPES: list[type] = []
_STORE_PROVIDERS = ("repro.memstore.store", "repro.distributed.sharded_lram")


def register_placement(name: str, factory: Callable) -> None:
    _PLACEMENT_FACTORIES[name] = factory


def register_kernel(kernel: str, storage_class: str, fn: Callable) -> None:
    _KERNEL_IMPLS[(kernel, storage_class)] = fn


def register_store_type(cls: type) -> None:
    global _store_types_cache
    if cls not in _STORE_TYPES:
        _STORE_TYPES.append(cls)
        _store_types_cache = None


def kernel_gather(kernel: str, storage_class: str) -> Callable:
    """The registered gather for (kernel, storage_class), importing its
    provider module on first use."""
    key = (kernel, storage_class)
    if key not in _KERNEL_IMPLS:
        provider = _KERNEL_PROVIDERS.get(key)
        if provider is None:
            raise KeyError(f"no kernel registered for {key}")
        importlib.import_module(provider)
    return _KERNEL_IMPLS[key]


_store_types_cache: tuple[type, ...] | None = None


def store_types() -> tuple[type, ...]:
    """Every registered offloaded-store class (providers imported).
    Memoized after the providers load: `is_store` sits on per-leaf
    checkpoint walks and per-apply validation."""
    global _store_types_cache
    if _store_types_cache is None:
        for provider in _STORE_PROVIDERS:
            importlib.import_module(provider)
        _store_types_cache = tuple(_STORE_TYPES)
    return _store_types_cache


def is_store(x) -> bool:
    return isinstance(x, store_types())


def find_stores(tree) -> list[tuple[str, Any]]:
    """(path, store) for every distinct offloaded store in a pytree."""
    import jax

    types = store_types()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, types)
    )
    out, seen = [], set()
    for path, leaf in flat:
        if isinstance(leaf, types) and id(leaf) not in seen:
            seen.add(id(leaf))
            name = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path
            )
            out.append((name, leaf))
    return out


def is_memory_table(x) -> bool:
    """A whole value-table object: a registered offloaded store or a
    dense `QuantizedTable` (treated as one leaf, not its q/scale parts)."""
    from repro.quant import QuantizedTable

    return is_store(x) or isinstance(x, QuantizedTable)


def map_memory_tables(tree, fn: Callable[[Any], Any]):
    """Replace every `lram/values` table leaf of a model-sized pytree with
    `fn(table)` — the shared walker behind `repro.memctl`'s growth and
    migration.  Tables are visited whole (`is_memory_table`), so a
    QuantizedTable maps as one object; works on params and on trees
    mirroring them (optimizer moments)."""
    import jax

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_memory_table
    )
    leaves = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        leaves.append(fn(leaf) if name.endswith("lram/values") else leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------

def resolve(cfg, override=None) -> LookupPlan:
    """Resolve a config (plus an optional per-call override) into a plan.

    `override` is ``lram_apply``'s ``interp_impl`` argument: ``None``
    (use ``cfg.interp_impl``) or an impl name string.

    Resolution happens once per (config, impl, ambient mesh) — the result
    is memoized, so ``lram_apply`` can call this on every trace without
    re-walking the registry.
    """
    impl = override if override is not None else cfg.interp_impl
    if not isinstance(impl, str):
        raise LookupPlanError(
            "custom", "?", "?",
            "callable interp_impl hooks were removed — pass an impl name "
            "(reference | pallas | tiered | sharded | sharded-tiered) or "
            "register a placement backend via repro.core.lookup",
        )
    from repro.distributed import context as _ctx

    return _resolve_cached(cfg, impl, _ctx.get_mesh())


@functools.lru_cache(maxsize=None)
def _resolve_cached(cfg, impl: str, mesh) -> LookupPlan:
    placement = IMPL_PLACEMENT.get(impl)
    if placement is None:
        raise LookupPlanError(
            impl, "?", "?",
            f"unknown interp_impl {impl!r}; known: {sorted(IMPL_PLACEMENT)}",
        )
    storage = _resolve_storage(cfg, placement)
    kernel = _resolve_kernel(cfg, placement, impl)
    factory = _placement_factory(placement)
    return factory(cfg, storage, kernel)


def _placement_factory(placement: str) -> Callable:
    if placement not in _PLACEMENT_FACTORIES:
        importlib.import_module(_PLACEMENT_PROVIDERS[placement])
    return _PLACEMENT_FACTORIES[placement]


def _resolve_storage(cfg, placement: str) -> str:
    storage = "fp32" if cfg.table_quant in (None, "none") else cfg.table_quant
    spec = getattr(cfg, "tiered", None)
    if placement in ("tiered", "sharded-tiered") and spec is not None \
            and spec.quant != "none":
        if storage not in ("fp32", spec.quant):
            raise LookupPlanError(
                placement, storage, "?",
                f"LRAMConfig.table_quant={storage!r} conflicts with "
                f"TieredSpec.quant={spec.quant!r}",
            )
        storage = spec.quant
    if storage not in STORAGES:
        raise LookupPlanError(
            placement, storage, "?",
            f"unknown storage {storage!r}; known: {STORAGES}",
        )
    return storage


def _resolve_kernel(cfg, placement: str, impl: str) -> str:
    kernel = getattr(cfg, "lookup_kernel", "auto")
    if kernel == "auto":
        if placement == "dense":
            kernel = "pallas" if impl == "pallas" else "reference"
        elif placement in ("tiered", "sharded-tiered"):
            spec = getattr(cfg, "tiered", None)
            kernel = "pallas" if (spec is not None and spec.use_pallas) \
                else "reference"
        else:
            kernel = "reference"
    if kernel not in KERNELS:
        raise LookupPlanError(
            placement, "?", kernel,
            f"unknown kernel {kernel!r}; known: {KERNELS}",
        )
    return kernel


def model_plans(model_cfg) -> list[LookupPlan]:
    """The resolved lookup plans a model config implies (one per distinct
    LRAM config; [] when the arch has no memory layer).  This is how the
    serve engine and the trainer discover capabilities — plan flags, not
    isinstance checks on params."""
    lram_cfg = getattr(model_cfg, "lram", None)
    if lram_cfg is None or not getattr(model_cfg, "lram_layers", ()):
        return []
    return [resolve(lram_cfg)]


# ---------------------------------------------------------------------------
# the dense placement (lives here: it is the reference semantics)
# ---------------------------------------------------------------------------

def _expect_dense(values, placement, storage, kernel):
    if is_store(values):
        raise LookupPlanError(
            placement, storage, kernel,
            "params['values'] is a tiered store but the plan expects a "
            "dense table — init and apply must use the same interp_impl",
        )


def _dense_factory(cfg, storage: str, kernel: str) -> LookupPlan:
    if storage == "fp32":
        from repro import quant

        gather = kernel_gather(kernel, "fp32")

        def interp(values, idx, w):
            _expect_dense(values, "dense", storage, kernel)
            if isinstance(values, quant.QuantizedTable):
                raise LookupPlanError(
                    "dense", storage, kernel,
                    "params['values'] is a QuantizedTable but the plan "
                    "expects an fp32 table — init and apply must use the "
                    "same table_quant",
                )
            return gather(values, idx, w)

        return LookupPlan(
            placement="dense", storage=storage, kernel=kernel,
            build_table=lambda dense: dense, interp=interp,
            supports_growth=True, supports_overlay=True,
        )

    from repro import quant

    quant.check_kind(storage)
    gather = kernel_gather(kernel, "quant")

    def interp(values, idx, w):
        _expect_dense(values, "dense", storage, kernel)
        if not isinstance(values, quant.QuantizedTable):
            raise LookupPlanError(
                "dense", storage, kernel,
                f"params['values'] must be a QuantizedTable for "
                f"storage={storage!r}; got {type(values).__name__}",
            )
        return gather(values, idx, w)

    return LookupPlan(
        placement="dense", storage=storage, kernel=kernel,
        build_table=lambda dense: quant.QuantizedTable.from_dense(
            dense, storage
        ),
        interp=interp,
        # integer payloads are opaque to autodiff: a dense quantized table
        # is a frozen store (training goes through the tiered write-back)
        table_update="frozen",
        supports_growth=True, supports_overlay=True,
    )


register_placement("dense", _dense_factory)


def read_rows_fp32(table, rows) -> Any:
    """Host-side fp32 read of arbitrary rows from any value-table object
    (dense array, `QuantizedTable`, tiered / sharded-tiered store), with
    the table's storage rounding applied.  The per-tenant overlay layer
    (`repro.serving.overlay`) diffs overlay rows against base rows read
    through this, so a plan only sets ``supports_overlay`` if its table
    kind is handled here.  Mirrors `repro.memctl.migrate._read_rows` but
    takes an arbitrary row-id array instead of a contiguous range."""
    import numpy as np

    rows = np.asarray(rows, np.int64).reshape(-1)
    if is_store(table):
        payload, scales = table._read_rows_raw(rows)
        if scales is None:
            return np.asarray(payload, np.float32)
        from repro import quant

        return quant.dequantize_rows_np(payload, scales)
    from repro import quant

    if isinstance(table, quant.QuantizedTable):
        q = np.asarray(table.q)[rows]
        scale = np.asarray(table.scale, np.float32)[rows]
        return quant.dequantize_rows_np(q, scale)
    return np.asarray(table, np.float32)[rows]


def merged_tiered_spec(cfg, storage: str, kernel: str):
    """The TieredSpec a tiered(-sharded) plan actually builds: the
    config's spec (or defaults) with the resolved storage and kernel axes
    folded in.  Shared by the tiered and sharded-tiered factories."""
    from repro.memstore import TieredSpec

    spec = getattr(cfg, "tiered", None) or TieredSpec()
    quant_kind = "none" if storage == "fp32" else storage
    if spec.quant != quant_kind or spec.use_pallas != (kernel == "pallas"):
        spec = dataclasses.replace(
            spec, quant=quant_kind, use_pallas=(kernel == "pallas")
        )
    return spec
