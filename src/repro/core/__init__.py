"""The paper's layer: E8 lattice memory with O(1) random-access lookup.

Public surface (one module per concern):

  * `repro.core.lattice`  — 232-candidate E8 neighbor enumeration and the
    interpolation kernel f(r) = max(0, 1 - r^2/8)^4
  * `repro.core.torus`    — torus_map: queries onto the fundamental domain
  * `repro.core.indexing` — lattice point <-> flat table index bijection
    (`TorusSpec`, `choose_torus`, `encode_points`, `decode_index`)
  * `repro.core.lram`     — `LRAMConfig`, `lram_init`/`lram_apply`, the
    memory-augmented FFN block
  * `repro.core.lookup`   — the lookup-backend registry: placement
    (dense | tiered | sharded | sharded-tiered) × storage (fp32 | int8 |
    fp8) × kernel (reference | pallas) resolved once into a `LookupPlan`
    (table construction, gather+interp, capability flags); backends
    self-register from kernels/, memstore/, and distributed/
  * `repro.core.pkm`      — Product-Key Memory baseline

Data flow and backward-pass contracts: docs/architecture.md.
"""
