"""The paper's layer: E8 lattice memory with O(1) random-access lookup.

Public surface (one module per concern):

  * `repro.core.lattice`  — 232-candidate E8 neighbor enumeration and the
    interpolation kernel f(r) = max(0, 1 - r^2/8)^4
  * `repro.core.torus`    — torus_map: queries onto the fundamental domain
  * `repro.core.indexing` — lattice point <-> flat table index bijection
    (`TorusSpec`, `choose_torus`, `encode_points`, `decode_index`)
  * `repro.core.lram`     — `LRAMConfig`, `lram_init`/`lram_apply`, the
    memory-augmented FFN block, and the `interp_impl` dispatch across the
    four lookup implementations (reference | pallas | tiered | sharded)
    plus quantized tables (`table_quant`)
  * `repro.core.pkm`      — Product-Key Memory baseline

Data flow and backward-pass contracts: docs/architecture.md.
"""
