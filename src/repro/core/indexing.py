"""Bijective indexing of torus memory locations.

Memory locations are the points of Lambda (the scaled E8 lattice, integer
coordinates) inside the fundamental box of the wrap lattice
L_K = prod_i (K_i Z).  For L_K to be a sublattice of Lambda every K_i must be
divisible by 4; the number of memory locations is

    N = |Lambda / L_K| = prod(K) / det(Lambda) = prod(K) / 256.

We need an O(1) bijection  Lambda ∩ prod [0, K_i)  <->  [0, N)  to address the
value table.  Using the coset decomposition

    Lambda = 2*D8 ∪ (2*D8 + (1,...,1)),      D8 = {u in Z^8 : sum(u) even}

every lattice point is  x = 2u + p*(1,...,1)  with parity bit p in {0,1} and
sum(u) even.  With M_i = K_i/2 (even), the wrap preserves the parity of
sum(u), and u_8's parity is determined by u_1..u_7 — so (u_1..u_7, u_8/2~, p)
is a mixed-radix integer.  Both directions are a handful of integer ops,
branch-free, vectorized.  This replaces the paper's CUDA index computation.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.core import lattice

_MIN_K = 8  # kernel radius sqrt(8) must be < K/2: smallest legal wrap is 8


@dataclasses.dataclass(frozen=True)
class TorusSpec:
    """Wrap lengths of the memory torus. K_i divisible by 4, >= 8."""

    K: tuple[int, ...]

    def __post_init__(self):
        if len(self.K) != lattice.DIM:
            raise ValueError(f"need {lattice.DIM} wrap lengths, got {self.K}")
        for k in self.K:
            if k < _MIN_K or k % 4 != 0:
                raise ValueError(
                    f"wrap length {k} must be >= {_MIN_K} and divisible by 4"
                )
        if self.num_locations >= 2**31:
            raise ValueError("num_locations must fit int32")

    @property
    def num_locations(self) -> int:
        return math.prod(self.K) // lattice.DET

    @property
    def M(self) -> tuple[int, ...]:
        return tuple(k // 2 for k in self.K)


def choose_torus(log2_locations: int) -> TorusSpec:
    """Pick power-of-two wrap lengths giving N = 2**log2_locations.

    N = prod(K)/256 with K_i = 2^(3+e_i)  =>  sum(e_i) = log2_locations - 16.
    The smallest representable memory is therefore 2^16 locations; extra
    factors of two are distributed round-robin (keeps the torus near-cubic,
    which maximises the covering quality of the wrapped lattice).
    """
    extra = log2_locations - 16
    if extra < 0:
        raise ValueError("lattice memory needs >= 2**16 locations (K_i >= 8)")
    exps = [3] * lattice.DIM
    for i in range(extra):
        exps[i % lattice.DIM] += 1
    spec = TorusSpec(tuple(2**e for e in sorted(exps, reverse=True)))
    assert spec.num_locations == 2**log2_locations
    return spec


def grow_torus(spec: TorusSpec, factor: int) -> TorusSpec:
    """The index-preserving enlargement of a torus: K_0 multiplied by
    `factor` (a power of two), all other wrap lengths unchanged.

    Why K_0: `encode_points` is a mixed-radix integer in (u_1..u_7, u_8, p)
    whose radices are M_1..M_7 — M_0 appears in no digit weight.  Enlarging
    K_0 therefore (a) keeps every lattice point of the old fundamental box
    at its *exact* old flat index, and (b) assigns the new points indices
    in [old_N, new_N).  That is what makes online capacity growth an
    append: old table rows, host shards, and device-cache slots all stay
    valid (`repro.memctl.growth`).  The cost is a torus that elongates
    along one axis instead of staying near-cubic (`choose_torus`), i.e. a
    slightly worse covering — the documented price of growing live instead
    of re-initialising.
    """
    if factor < 2 or factor & (factor - 1):
        raise ValueError(f"growth factor must be a power of two >= 2, "
                         f"got {factor}")
    return TorusSpec((spec.K[0] * factor,) + spec.K[1:])


def growth_parents(old_spec: TorusSpec, new_spec: TorusSpec,
                   lo: int, hi: int) -> np.ndarray:
    """Old-table parent row for each new row id in [lo, hi).

    A new row's lattice point, wrapped onto the *old* torus (mod old K),
    lands on the old lattice point that served its queries before growth —
    its nearest coarse-lattice parent.  Initialising the new row from that
    parent makes pre-growth lookups reproduce exactly: the kernel weights
    depend only on query/point geometry, and the gathered values are
    bit-identical copies.

    For `grow_torus` enlargements this reduces to ``j % old_N`` (the grown
    table is an alias stack of the old one) — asserted in tests; computed
    here from the lattice bijection so any compatible (old, new) pair
    works.
    """
    for ko, kn in zip(old_spec.K, new_spec.K):
        if kn % ko:
            raise ValueError(
                f"new wrap lengths {new_spec.K} must be componentwise "
                f"multiples of old {old_spec.K}"
            )
    pts = decode_index(np.arange(lo, hi, dtype=np.int64), new_spec)
    return np.asarray(encode_points(jnp.asarray(pts), old_spec),
                      dtype=np.int64)


def encode_points(x: jnp.ndarray, spec: TorusSpec) -> jnp.ndarray:
    """Map lattice points (..., 8) (any integer coords) to flat indices.

    Points are wrapped onto the torus first (mod K), so callers can pass the
    un-wrapped neighbor coordinates straight from the decoder.
    """
    K = jnp.asarray(spec.K, dtype=jnp.int32)
    M = jnp.asarray(spec.M, dtype=jnp.int32)
    xi = jnp.round(x).astype(jnp.int32)
    xm = jnp.mod(xi, K)
    p = xm[..., 0] & 1
    u = (xm - p[..., None]) >> 1  # (..., 8), u_i in [0, M_i)
    qpar = jnp.sum(u[..., :7], axis=-1) & 1
    j8 = (u[..., 7] - qpar) >> 1
    idx7 = jnp.zeros_like(p)
    for i in range(7):
        idx7 = idx7 * M[i] + u[..., i]
    return (idx7 * (M[7] >> 1) + j8) * 2 + p


def decode_index(idx: np.ndarray, spec: TorusSpec) -> np.ndarray:
    """Inverse of :func:`encode_points` (numpy; used by tests/analysis)."""
    idx = np.asarray(idx, dtype=np.int64)
    M = spec.M
    p = idx & 1
    r = idx >> 1
    half = M[7] >> 1
    j8 = r % half
    idx7 = r // half
    u = np.zeros(idx.shape + (lattice.DIM,), dtype=np.int64)
    for i in reversed(range(7)):
        u[..., i] = idx7 % M[i]
        idx7 = idx7 // M[i]
    qpar = u[..., :7].sum(axis=-1) & 1
    u[..., 7] = 2 * j8 + qpar
    return 2 * u + p[..., None]
