"""The 2*E8 lattice: decoding, canonicalization, and the 232-candidate table.

The paper ("Differentiable Random Access Memory using Lattices", Goucher &
Troll 2021, §2.4-2.6) works with a copy of the E8 lattice scaled by 2 so that
all lattice points have integer coordinates:

    Lambda := { x in (2Z)^8 ∪ (2Z+1)^8  :  sum(x) ≡ 0 (mod 4) }

Equivalently Lambda = 2*D8 ∪ (2*D8 + 1) where D8 = {u in Z^8 : sum(u) even}.
Key constants (all asserted in tests against the paper):

  * minimum distance between lattice points:  sqrt(8)
  * packing radius sqrt(2), covering radius 2
  * kernel  f(r) = max(0, 1 - r^2/8)^4  vanishes exactly at the minimum
    distance, so phi(k) = v_k at every lattice point
  * exactly 232 lattice points lie within distance < sqrt(8) of the
    fundamental region F (paper §2.6)
  * average kernel-support size = V_8(sqrt 8)/det = pi^4*4096/24/256 = 64.94

This module provides BOTH the exact numpy precomputation (candidate table,
used once at import of the table) and the batched jax ops used inside the
neural network (decode / canonicalize / neighbor enumeration).  Everything is
branch-free and lane-parallel: this is the TPU-native adaptation of the
paper's per-thread CUDA decoder (see DESIGN.md §3).
"""

from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Constants
# ---------------------------------------------------------------------------

DIM = 8
#: squared kernel radius == squared minimum distance of the lattice
RADIUS_SQ = 8.0
PACKING_RADIUS = np.sqrt(2.0)
COVERING_RADIUS = 2.0
#: determinant (covolume) of the scaled lattice: 2^8 * det(E8) = 256
DET = 256
#: number of lattice points within sqrt(8) of the fundamental region (paper)
NUM_CANDIDATES = 232
#: paper keeps only the top-32 closest points (>=90% of kernel mass)
DEFAULT_TOP_K = 32
#: lower bound for the total kernel weight, (22158 - 625*sqrt(5))/24389
WEIGHT_LOWER_BOUND = (22158.0 - 625.0 * np.sqrt(5.0)) / 24389.0  # ~0.8507
#: analytic mean number of support points: V8(sqrt8)/DET
MEAN_SUPPORT = float(np.pi**4 * (8.0**4) / 24.0 / DET)  # 64.939...


# ---------------------------------------------------------------------------
# Exact shell enumeration (numpy, used for the candidate-table precompute and
# as a brute-force oracle in tests)
# ---------------------------------------------------------------------------

def _shell8() -> np.ndarray:
    """All 240 lattice vectors with squared norm 8."""
    out = []
    # even type: two coordinates +-2, rest 0  -> C(8,2)*4 = 112
    for i, j in itertools.combinations(range(DIM), 2):
        for si in (2, -2):
            for sj in (2, -2):
                v = np.zeros(DIM, dtype=np.int64)
                v[i], v[j] = si, sj
                out.append(v)
    # odd type: (+-1)^8 with an even number of minus signs -> 128
    for signs in itertools.product((1, -1), repeat=DIM):
        if signs.count(-1) % 2 == 0:
            out.append(np.array(signs, dtype=np.int64))
    arr = np.stack(out)
    assert arr.shape == (240, DIM)
    return arr


def _shell16() -> np.ndarray:
    """All 2160 lattice vectors with squared norm 16."""
    out = []
    # (+-4, 0^7) -> 16
    for i in range(DIM):
        for s in (4, -4):
            v = np.zeros(DIM, dtype=np.int64)
            v[i] = s
            out.append(v)
    # four coordinates +-2 -> C(8,4)*16 = 1120  (sum always ≡ 0 mod 4)
    for pos in itertools.combinations(range(DIM), 4):
        for signs in itertools.product((2, -2), repeat=4):
            v = np.zeros(DIM, dtype=np.int64)
            for p, s in zip(pos, signs):
                v[p] = s
            out.append(v)
    # (+-3, +-1^7) with sum ≡ 0 mod 4 -> 8*128 = 1024
    for i in range(DIM):
        for signs in itertools.product((1, -1), repeat=DIM):
            v = np.array(signs, dtype=np.int64)
            v[i] *= 3
            if v.sum() % 4 == 0:
                out.append(v)
    arr = np.stack(out)
    assert arr.shape == (2160, DIM), arr.shape
    return arr


@functools.lru_cache(maxsize=None)
def shell_vectors() -> np.ndarray:
    """All 2401 lattice vectors with squared norm <= 16 (shells 0, 8, 16).

    Any lattice point within sqrt(8) of the fundamental region F (whose
    points have norm <= covering radius 2) has norm < 2 + sqrt(8) < sqrt(24),
    hence lies in one of these shells.
    """
    return np.concatenate(
        [np.zeros((1, DIM), dtype=np.int64), _shell8(), _shell16()], axis=0
    )


def is_lattice_point(x: np.ndarray) -> np.ndarray:
    """Boolean mask: is x (integer array, (..., 8)) a point of Lambda."""
    x = np.asarray(x)
    par = np.mod(x, 2)
    same_parity = np.all(par == par[..., :1], axis=-1)
    sum_ok = np.mod(x.sum(axis=-1), 4) == 0
    return same_parity & sum_ok


# ---------------------------------------------------------------------------
# Fundamental region F and the exact candidate table
#
# F = { z : z1>=z2>=...>=z7>=|z8|,  z1+z2 <= 2,  sum(z) <= 4 }
# (paper §2.6).  We compute, for every shell vector p, the exact Euclidean
# distance d(p, F) by enumerating KKT active sets of the projection QP
# min ||x-p||^2 s.t. A x <= b  -- exact up to numerical linear algebra,
# no iterative solver involved.
# ---------------------------------------------------------------------------

def _halfspaces() -> tuple[np.ndarray, np.ndarray]:
    A, b = [], []
    for i in range(7):  # z_{i+1} - z_i <= 0  (includes z8 <= z7)
        row = np.zeros(DIM)
        row[i + 1], row[i] = 1.0, -1.0
        A.append(row)
        b.append(0.0)
    row = np.zeros(DIM)  # -z7 - z8 <= 0
    row[6], row[7] = -1.0, -1.0
    A.append(row)
    b.append(0.0)
    row = np.zeros(DIM)  # z1 + z2 <= 2
    row[0], row[1] = 1.0, 1.0
    A.append(row)
    b.append(2.0)
    A.append(np.ones(DIM))  # sum z <= 4
    b.append(4.0)
    return np.stack(A), np.array(b)


def distance_sq_to_fundamental_region(points: np.ndarray) -> np.ndarray:
    """Exact squared distance from each point (M, 8) to the polytope F.

    Enumerates all 2^10 subsets of active constraints; for each, solves the
    equality-constrained projection in closed form and keeps KKT-valid
    solutions.  The projection onto a convex set is unique, so any valid
    active set yields the answer.
    """
    A, b = _halfspaces()
    m = A.shape[0]
    pts = np.asarray(points, dtype=np.float64)
    best = np.full(pts.shape[0], np.inf)
    feas_tol, dual_tol = 1e-9, -1e-9
    all_resid = pts @ A.T - b  # (M, m)
    # empty active set: point already in F
    inside = np.all(all_resid <= feas_tol, axis=1)
    best[inside] = 0.0
    for r in range(1, m + 1):
        for subset in itertools.combinations(range(m), r):
            S = list(subset)
            As = A[S]  # (r, 8)
            G = As @ As.T
            Ginv = np.linalg.pinv(G)
            resid = all_resid[:, S]  # (M, r)
            lam = resid @ Ginv.T  # (M, r)
            if r > DIM:  # can't have >8 independent constraints
                pass
            x = pts - lam @ As  # (M, 8)
            # validity: dual feasible, primal feasible, equality consistent
            ok = np.all(lam >= dual_tol, axis=1)
            ok &= np.all(x @ A.T - b <= feas_tol, axis=1)
            ok &= np.all(np.abs(x @ As.T - b[S]) <= 1e-7, axis=1)
            d2 = ((pts - x) ** 2).sum(axis=1)
            best = np.where(ok, np.minimum(best, d2), best)
    assert np.all(np.isfinite(best)), "projection failed for some point"
    return best


@functools.lru_cache(maxsize=None)
def candidate_table() -> np.ndarray:
    """The (232, 8) int table of lattice points within < sqrt(8) of F.

    This is the paper's precomputed array (§2.6): for a canonicalized query
    z in F, every lattice point within the kernel radius of z appears here.
    Sorted lexicographically for determinism.
    """
    shells = shell_vectors()
    d2 = distance_sq_to_fundamental_region(shells.astype(np.float64))
    keep = d2 < RADIUS_SQ - 1e-7
    cands = shells[keep]
    order = np.lexsort(cands.T[::-1])
    cands = cands[order]
    assert cands.shape == (NUM_CANDIDATES, DIM), (
        f"expected {NUM_CANDIDATES} candidates, got {cands.shape[0]}"
    )
    return cands


@functools.lru_cache(maxsize=None)
def candidate_arrays() -> tuple[np.ndarray, np.ndarray]:
    """float32 candidate table and its squared norms (for the MXU matmul)."""
    c = candidate_table().astype(np.float32)
    return c, (c * c).sum(axis=1)


# ---------------------------------------------------------------------------
# Kernel function (paper §2.5)
# ---------------------------------------------------------------------------

def kernel_from_sq(d2: jax.Array) -> jax.Array:
    """f(r) = max(0, 1 - r^2/8)^4 computed from the squared distance."""
    t = jnp.maximum(0.0, 1.0 - d2 / RADIUS_SQ)
    t2 = t * t
    return t2 * t2


# ---------------------------------------------------------------------------
# Nearest-point decoding (Conway & Sloane), batched & branch-free
# ---------------------------------------------------------------------------

def _decode_d8(u: jax.Array) -> jax.Array:
    """Nearest point of D8 = {x in Z^8 : sum(x) even} to u (..., 8)."""
    r = jnp.round(u)
    delta = u - r  # in [-0.5, 0.5]
    # If the coordinate-wise rounding has odd sum, re-round the coordinate
    # with the largest rounding error to the next-nearest integer.
    worst = jnp.argmax(jnp.abs(delta), axis=-1)
    flip = jnp.where(delta >= 0, 1.0, -1.0)
    onehot = jax.nn.one_hot(worst, DIM, dtype=u.dtype)
    r_alt = r + onehot * jnp.take_along_axis(
        flip, worst[..., None], axis=-1
    )
    odd = jnp.mod(jnp.sum(r, axis=-1), 2.0) != 0
    return jnp.where(odd[..., None], r_alt, r)


def decode(q: jax.Array) -> jax.Array:
    """Nearest point of Lambda = 2*D8 ∪ (2*D8+1) to q (..., 8).

    Exact: decodes both cosets and keeps the closer one.
    """
    even = 2.0 * _decode_d8(q * 0.5)
    odd = 2.0 * _decode_d8((q - 1.0) * 0.5) + 1.0
    de = jnp.sum((q - even) ** 2, axis=-1)
    do = jnp.sum((q - odd) ** 2, axis=-1)
    return jnp.where((de <= do)[..., None], even, odd)


def canonicalize(t: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Map a Voronoi-cell offset t = q - decode(q) into F.

    Returns (z, perm, sgn) with  z_j = sgn_j * t[perm_j]  in F:
      * coordinates sorted by decreasing absolute value,
      * first seven nonnegative; the last carries the sign parity (the
        isometry group only allows an even number of sign flips).
    """
    at = jnp.abs(t)
    # The permutation is piecewise-constant in t, so sorting under
    # stop_gradient is exact a.e. (and avoids the non-differentiable
    # sort-gradient path entirely).
    perm = jnp.argsort(-jax.lax.stop_gradient(at), axis=-1, stable=True)
    tp = jnp.take_along_axis(t, perm, axis=-1)
    sgn = jnp.where(tp < 0, -1.0, 1.0).astype(t.dtype)
    parity = jnp.prod(sgn, axis=-1, keepdims=True)  # (-1)^{#negatives}
    sgn = sgn.at[..., 7:8].multiply(parity)
    z = sgn * tp
    return z, perm, sgn


def neighbors_and_weights(
    q: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """All 232 candidate lattice points near q, with kernel weights.

    Args:
      q: (..., 8) query points (any reals; torus reduction happens at
        indexing time since the kernel radius is < half the wrap period).

    Returns:
      neighbors: (..., 232, 8) lattice points (global, un-wrapped coords)
      weights:   (..., 232) kernel weights f(d(q, k)); zero outside support.

    Differentiable in q almost everywhere: the isometry (decode / perm /
    signs) is locally constant, distances are computed in the canonical
    frame where they are smooth functions of q.
    """
    cand, cand_nsq = candidate_arrays()
    cand = jnp.asarray(cand, dtype=q.dtype)
    cand_nsq = jnp.asarray(cand_nsq, dtype=q.dtype)
    c = decode(q)
    z, perm, sgn = canonicalize(q - c)
    # squared distances to all candidates via one (..., 8) @ (8, 232) matmul
    d2 = (
        jnp.sum(z * z, axis=-1, keepdims=True)
        - 2.0 * (z @ cand.T)
        + cand_nsq
    )
    w = kernel_from_sq(d2)
    # undo the isometry:  k[perm_j] = sgn_j * p_j + c[perm_j]
    inv = jnp.argsort(perm, axis=-1, stable=True)
    sp = sgn[..., None, :] * cand  # (..., 232, 8)
    glob = jnp.take_along_axis(
        sp, jnp.broadcast_to(inv[..., None, :], sp.shape), axis=-1
    )
    neighbors = c[..., None, :] + glob
    return neighbors, w


def brute_force_neighbors(q: np.ndarray, radius_sq: float = RADIUS_SQ):
    """Oracle: all lattice points within sqrt(radius_sq) of a single query.

    Exhaustive over the <=sqrt(24) shells around the decoded center; used in
    tests to certify the candidate-table pipeline is complete.
    """
    q = np.asarray(q, dtype=np.float64)
    c = np.asarray(decode(jnp.asarray(q))).astype(np.int64)
    pts = c + shell_vectors()
    d2 = ((pts - q) ** 2).sum(axis=1)
    return pts[d2 < radius_sq - 1e-9], d2[d2 < radius_sq - 1e-9]
