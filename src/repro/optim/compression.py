"""Gradient compression with error feedback (distributed-optimization trick).

Two codecs for the data-parallel all-reduce:

  * int8   — per-leaf-block symmetric quantization (4x bandwidth saving on
             f32 grads); the quantization residual is fed back into the next
             step's gradient (error feedback, Seide et al. / EF-SGD), which
             keeps SGD/Adam convergence intact.
  * topk   — magnitude top-k sparsification (keep fraction rho), residual
             accumulated likewise.

The codec is applied to gradients before the optimizer; on a mesh the
quantized representation is what crosses the DP axis (see
repro.distributed.collectives.compressed_psum for the wire form).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionState:
    kind: str  # none | int8 | topk
    rho: float = 0.01  # topk keep fraction


def compression_init(params, kind: str = "none", rho: float = 0.01):
    if kind == "none":
        return {"kind": kind, "residual": None}
    residual = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"kind": kind, "rho": rho, "residual": residual}


def _quant_int8(g):
    # the shared int8 grid (repro.quant, also the value-table storage
    # codec); per-leaf here, with the residual fed back by the caller
    # instead of stochastic rounding
    from repro import quant

    return quant.int8_qdq(g)


def _topk_mask(g, rho: float):
    flat = jnp.abs(g).reshape(-1)
    k = max(1, int(rho * flat.shape[0]))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress_gradients(grads, comp_state):
    """Returns (decompressed_grads, new_comp_state)."""
    kind = comp_state["kind"]
    if kind == "none":
        return grads, comp_state

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        if kind == "int8":
            sent = _quant_int8(g32)
        elif kind == "topk":
            sent = _topk_mask(g32, comp_state["rho"])
        else:
            raise ValueError(kind)
        return sent.astype(g.dtype), g32 - sent

    out = jax.tree.map(one, grads, comp_state["residual"])
    sent = jax.tree.map(lambda x: x[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda x: x[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return sent, dict(comp_state, residual=resid)
