"""Adam with per-group learning rates (built from scratch — no optax here).

The paper trains "normal" parameters at 1e-4 and memory-layer values at 1e-3
"to compensate for sparse access" (§3.2).  Param groups are selected by
path substring match on the flattened tree (the LRAM/PKM value tables live
under ".../values").  Global-norm clipping and the usual schedules included.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    lr: float = 1e-4
    memory_lr_mult: float = 10.0   # paper: 1e-3 for memory values
    memory_path: str = "values"
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    schedule: str = "constant"     # constant | cosine | linear
    warmup_steps: int = 0
    total_steps: int = 100_000


def schedule_lr(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.warmup_steps > 0:
        lr = lr * jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    if cfg.schedule == "cosine":
        frac = jnp.clip(step / max(1, cfg.total_steps), 0.0, 1.0)
        lr = lr * 0.5 * (1.0 + jnp.cos(np.pi * frac))
    elif cfg.schedule == "linear":
        frac = jnp.clip(step / max(1, cfg.total_steps), 0.0, 1.0)
        lr = lr * (1.0 - frac)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def _lr_mult_tree(params, cfg: OptimConfig):
    """Per-leaf multiplier: memory value tables get memory_lr_mult."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    mults = []
    for path, _ in flat:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        mults.append(
            cfg.memory_lr_mult if cfg.memory_path in name else 1.0
        )
    return jax.tree_util.tree_unflatten(treedef, mults)


def adam_init(params) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adam_update(grads, opt_state, params, cfg: OptimConfig):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    lr = schedule_lr(cfg, step)
    mults = _lr_mult_tree(params, cfg)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(g, m, v, p, mult):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = lr * mult * mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0:
            delta = delta + lr * mult * cfg.weight_decay * p.astype(
                jnp.float32
            )
        return (p.astype(jnp.float32) - delta).astype(p.dtype), m_new, v_new

    out = jax.tree.map(
        upd, grads, opt_state["mu"], opt_state["nu"], params, mults
    )
    new_params = jax.tree.map(
        lambda x: x[0], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_mu = jax.tree.map(
        lambda x: x[1], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_nu = jax.tree.map(
        lambda x: x[2], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
