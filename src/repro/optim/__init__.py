from repro.optim.adam import (  # noqa: F401
    OptimConfig,
    adam_init,
    adam_update,
    global_norm,
    schedule_lr,
)
from repro.optim.compression import (  # noqa: F401
    CompressionState,
    compress_gradients,
    compression_init,
)
