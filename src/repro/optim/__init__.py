"""Optimizers and gradient codecs.

Public surface: `OptimConfig` / `adam_init` / `adam_update` (Adam with the
paper's 10x memory-value LR group; tiered stores are leafless and own
their write-back step instead), `schedule_lr`, `global_norm`, and the
all-reduce gradient codecs `compression_init` / `compress_gradients`
(int8 with error feedback — the same symmetric grid as the `repro.quant`
table codec — and magnitude top-k).
"""

from repro.optim.adam import (  # noqa: F401
    OptimConfig,
    adam_init,
    adam_update,
    global_norm,
    schedule_lr,
)
from repro.optim.compression import (  # noqa: F401
    CompressionState,
    compress_gradients,
    compression_init,
)
