"""Low-precision value-table storage: per-row symmetric quantization.

The LRAM value table is the memory-layer parameter that dominates bytes
(N * m floats); Memory Layers at Scale (Berges et al., 2024) and
Product-Key Memories (Lample et al., 2019) both show such tables tolerate
low-precision storage with negligible quality loss.  This module is the
single source of truth for how this repo stores a table row in fewer bits:

  * **int8**  — symmetric, per-row fp32 scale ``s_r = max|v_r| / 127``;
    stored row is ``round(v_r / s_r)`` in int8, dequant is ``q * s_r``.
  * **fp8**   — ``float8_e4m3fn`` payload (via ml_dtypes, which JAX already
    depends on) with per-row scale ``max|v_r| / 448`` mapping each row onto
    the format's full dynamic range.

Per *row* because a lookup touches whole rows: the gather can fetch the
row's scale alongside its payload and dequantize in-register, so the
weighted interpolation still runs in fp32 while rows move (HBM->VMEM, or
host->device in the tiered store) at 1 byte/element.  ``m`` floats of
payload become ``m`` bytes + one fp32 scale: 68 B vs 256 B per entry at
the paper's m=64 — a 3.76x capacity multiplier.

Write-back training on a quantized table uses **stochastic rounding**
(``round_mode="stochastic"``): ``floor(x + u)`` with ``u ~ U[0, 1)`` is
unbiased (``E[floor(x+u)] = x``), so the sparse SGD step survives
requantization in expectation even when single updates are smaller than
one quantization step.  The int8 gradient codec in `repro.optim.compression`
uses the same grid through `int8_qdq` below (its in-graph jnp form;
`quantize_int8` is the host-side numpy form the tiered store uses).

Dense (non-tiered) quantized tables live in a `QuantizedTable` pytree so
they ride ``params["values"]`` through jit; integer payloads are naturally
opaque to autodiff (float0 tangents), matching the tiered store's stance
that the table owns its own update rule.  Placement of the dequant in each
lookup path is mapped in docs/architecture.md.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

try:  # ml_dtypes ships with jax; guard anyway so int8 works without it
    import ml_dtypes

    _FP8_DTYPE: Any = np.dtype(ml_dtypes.float8_e4m3fn)
except ImportError:  # pragma: no cover - container always has ml_dtypes
    _FP8_DTYPE = None

QUANT_KINDS = ("int8", "fp8")

_EPS = 1e-12
_QMAX = {"int8": 127.0, "fp8": 448.0}  # float8_e4m3fn max finite


def check_kind(kind: str) -> str:
    if kind not in QUANT_KINDS:
        raise ValueError(f"unknown quant kind {kind!r}; known: {QUANT_KINDS}")
    if kind == "fp8" and _FP8_DTYPE is None:
        raise ValueError("fp8 tables need ml_dtypes (pip dep of jax)")
    return kind


def storage_dtype(kind: str) -> np.dtype:
    """numpy dtype of the stored payload (1 byte/element for both kinds)."""
    check_kind(kind)
    return np.dtype(np.int8) if kind == "int8" else _FP8_DTYPE


def qmax(kind: str) -> float:
    check_kind(kind)
    return _QMAX[kind]


def bytes_per_entry(m: int, kind: str | None) -> int:
    """Storage bytes for one (m,)-row: payload + per-row fp32 scale."""
    if kind in (None, "none"):
        return 4 * m
    check_kind(kind)
    return m * storage_dtype(kind).itemsize + 4


# ---------------------------------------------------------------------------
# numpy (host-side: tiered shards, write-back, checkpoints)
# ---------------------------------------------------------------------------

def quantize_int8(x: np.ndarray, *, axis=None, rng=None):
    """Symmetric int8 quantization: returns (q int8, scale fp32).

    axis=None  -> one scale for the whole array (the gradient-codec form);
    axis=-1    -> one scale per row (the value-table form).
    rng        -> stochastic rounding (unbiased); None rounds to nearest.
    """
    x = np.asarray(x, np.float32)
    amax = np.abs(x).max(axis=axis, keepdims=axis is not None)
    scale = np.maximum(amax, _EPS) / 127.0
    y = x / scale
    if rng is None:
        q = np.rint(y)
    else:
        q = np.floor(y + rng.random(y.shape, dtype=np.float32))
    q = np.clip(q, -127, 127).astype(np.int8)
    return q, np.squeeze(scale, axis) if axis is not None else float(scale)


def quantize_rows_np(v: np.ndarray, kind: str, *, rng=None):
    """Per-row quantization of (..., m) values -> (q, scale (...,)).

    int8 supports stochastic rounding via `rng`; fp8 rounds to nearest
    (its non-uniform grid has no single-step SR form — documented in
    docs/memstore.md; the unbiasedness test covers int8, the write-back
    dtype).
    """
    check_kind(kind)
    v = np.asarray(v, np.float32)
    if kind == "int8":
        return quantize_int8(v, axis=-1, rng=rng)
    amax = np.abs(v).max(axis=-1)
    scale = (np.maximum(amax, _EPS) / _QMAX["fp8"]).astype(np.float32)
    q = (v / scale[..., None]).astype(_FP8_DTYPE)
    return q, scale


def dequantize_rows_np(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    """fp32 rows from (q (..., m), scale (...,))."""
    return q.astype(np.float32) * np.asarray(scale, np.float32)[..., None]


# ---------------------------------------------------------------------------
# jnp (device-side: dense quantized tables, in-graph dequant)
# ---------------------------------------------------------------------------

def jnp_storage_dtype(kind: str):
    check_kind(kind)
    return jnp.int8 if kind == "int8" else jnp.float8_e4m3fn


def int8_qdq(x: jax.Array) -> jax.Array:
    """In-graph symmetric int8 quantize->dequantize (one scale per array):
    what survives an int8 wire format.  Used by the gradient codec in
    `repro.optim.compression` (which feeds the residual back) — the same
    grid the value-table storage uses per row."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), _EPS) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def dequantize_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTable:
    """A dense (N, m) value table stored quantized with per-row scales.

    Sits at ``params["values"]`` in place of the fp32 array; the reference
    and Pallas lookup paths detect it and dequantize at gather time.  The
    payload is an integer (or fp8) pytree leaf, so autodiff yields no
    cotangent for it — a quantized dense table is a frozen lookup store
    (training a quantized table goes through the tiered store's
    stochastic-rounding write-back instead).
    """

    q: jax.Array       # (N, m) int8 | float8_e4m3fn
    scale: jax.Array   # (N,) fp32
    kind: str = "int8"

    def tree_flatten(self):
        return (self.q, self.scale), self.kind

    @classmethod
    def tree_unflatten(cls, kind, children):
        q, scale = children
        return cls(q=q, scale=scale, kind=kind)

    @property
    def shape(self):
        return self.q.shape

    @property
    def num_rows(self) -> int:
        return self.q.shape[0]

    @property
    def m(self) -> int:
        return self.q.shape[-1]

    def dequantize(self) -> jax.Array:
        return dequantize_rows(self.q, self.scale)

    @classmethod
    def from_dense(cls, values, kind: str) -> "QuantizedTable":
        q, scale = quantize_rows_np(np.asarray(values), check_kind(kind))
        return cls(q=jnp.asarray(q), scale=jnp.asarray(scale), kind=kind)


def gather_interp_quant(table: QuantizedTable, idx: jax.Array,
                        w: jax.Array) -> jax.Array:
    """sum_k w_k * dequant(q[idx_k]) -> (..., m).  Reference path: rows are
    gathered in their 1-byte form and dequantized in-graph, so the weighted
    sum runs in fp32 but the table reads move 4x fewer bytes."""
    rows = jnp.take(table.q, idx, axis=0)
    scales = jnp.take(table.scale, idx, axis=0)
    return jnp.einsum(
        "...k,...km->...m", w.astype(jnp.float32),
        dequantize_rows(rows, scales),
    )


def max_abs_error_bound(scale, w, kind: str = "int8") -> float:
    """Documented agreement bound between a quantized lookup and its fp32
    reference:  |out_q - out_fp32| <= sum_k |w_k| * max_r(scale_r) * h

    where h is the half-step of the storage grid in scale units: 1/2 for
    int8 (uniform grid, step = scale), and 448 * 2**-4 = 28 for fp8 — an
    e4m3 value rounds within 2**-4 of its magnitude, and magnitudes reach
    448 * scale at the row max.  The quantization tests assert this bound
    for every lookup implementation."""
    half_step = 0.5 if check_kind(kind) == "int8" else _QMAX["fp8"] / 16.0
    return float(
        np.max(np.sum(np.abs(np.asarray(w)), axis=-1))
        * np.max(np.asarray(scale)) * half_step
    )
