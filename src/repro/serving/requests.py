"""Requests, arrival traces, and the admission queue for the serve engine.

A `Request` is one generation job: a prompt, a generation budget, and an
arrival time.  `synthetic_trace` builds the mixed-length open-loop traces
the benchmarks replay (Poisson arrivals at a configurable offered load;
`rate=0` degenerates to the closed-loop "everything queued at t=0" case
tests use).  `RequestQueue` is the engine-facing view: requests become
*ready* when the engine clock passes their arrival time, in arrival order.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request (prompt tokens + decode budget)."""

    id: int
    prompt: np.ndarray          # (S,) int32 token ids, S >= 1
    max_new_tokens: int         # number of tokens to generate (>= 1)
    arrival_s: float = 0.0      # seconds since trace start
    tenant_id: str | None = None  # per-tenant memory overlay key
    #                               (None = anonymous: base table only)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.id}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.id}: max_new_tokens must be >=1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)


def synthetic_trace(
    rng: np.random.Generator,
    num_requests: int,
    *,
    vocab_size: int,
    max_prompt: int,
    max_gen: int,
    rate: float = 0.0,
    mixed: bool = True,
    tenants: int = 0,
) -> list[Request]:
    """Mixed-length request trace with Poisson arrivals.

    `mixed=True` draws prompt lengths uniformly from [1, max_prompt] and
    generation budgets from [1, max_gen] — the head-of-line-blocking regime
    where continuous batching beats the fixed-batch loop.  `mixed=False`
    pins every request to (max_prompt, max_gen), reproducing the legacy
    fixed-shape workload.  `rate` is the offered load in requests/second;
    0 means every request is queued at t=0 (closed loop).  `tenants > 0`
    assigns each request a random tenant id from a pool of that size
    (``"t0".."t{n-1}"``) for the per-tenant memory overlays; 0 keeps the
    trace anonymous (and draws no extra random numbers, so existing
    seeded traces are unchanged).
    """
    reqs = []
    t = 0.0
    for i in range(num_requests):
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        s = int(rng.integers(1, max_prompt + 1)) if mixed else max_prompt
        g = int(rng.integers(1, max_gen + 1)) if mixed else max_gen
        tenant = f"t{int(rng.integers(0, tenants))}" if tenants > 0 else None
        reqs.append(Request(
            id=i,
            prompt=rng.integers(0, vocab_size, size=(s,)).astype(np.int32),
            max_new_tokens=g,
            arrival_s=t,
            tenant_id=tenant,
        ))
    return reqs


class RequestQueue:
    """Arrival-ordered admission queue driven by the engine clock."""

    def __init__(self, requests: list[Request] = ()):  # noqa: B006 - tuple
        self._pending: list[Request] = sorted(
            requests, key=lambda r: (r.arrival_s, r.id)
        )

    def __len__(self) -> int:
        return len(self._pending)

    def push(self, request: Request) -> None:
        """Insert keeping arrival order (the real-entrypoint hook)."""
        self._pending.append(request)
        self._pending.sort(key=lambda r: (r.arrival_s, r.id))

    def next_arrival(self) -> float | None:
        """Arrival time of the earliest pending request (None if empty)."""
        return self._pending[0].arrival_s if self._pending else None

    def num_ready(self, now: float) -> int:
        return sum(1 for r in self._pending if r.arrival_s <= now)

    def pop_ready(self, now: float) -> Request | None:
        """Earliest request that has arrived by `now`, or None."""
        if self._pending and self._pending[0].arrival_s <= now:
            return self._pending.pop(0)
        return None
