"""Per-tenant copy-on-write memory overlays over one shared base table.

Serving "millions of users" from one lattice table means each tenant
needs *their own view* of that table without duplicating it.  A
`TenantOverlay` is that view: a small sparse set of rows per lram layer,
stored in the **same storage kind as the base table** (fp32 rows, or
1-byte payload + per-row scale via `repro.quant` — identical rounding to
the base, so overlay reads compose with dense/tiered/sharded-tiered ×
fp32/int8/fp8 plans alike).  A row present in the overlay shadows the
base row; absent rows read through to the base unchanged.

`OverlayManager` is the serve-engine side:

  * **attach/detach** — the engine binds a tenant to a decode slot at
    admission and releases it at retirement.  The manager maintains
    fixed-shape per-slot *packs* (`ids` (L, B, C) int32, `deltas`
    (L, B, C, m) fp32 with ``delta = dequant(overlay_row) - base_row``)
    that the jitted steps consume through `repro.core.overlay` — packs
    are mutated in place on the host, so attach/detach never recompiles.
    An overlay holds at most C (= pack capacity) rows per layer, so the
    pack always covers the whole overlay.
  * **writeback** — after each decode tick the engine hands back the
    tick's lattice accesses; the manager applies a Hebbian update
    ``row <- row + lr * Σ w_k · y_head`` to each accessed row of the
    slot's tenant (copy-on-write: the base row is read once, then the
    tenant owns their copy).  Inference-time memory, not SGD.
  * **lifecycle** — `enforce` (driven by `repro.memctl` on the engine
    tick) expires idle tenants past their TTL and spills
    least-recently-used tenants to host ``.npz`` files when the byte
    budget is exceeded; a spilled tenant restores transparently on next
    attach.  Attached tenants are never touched, so in-flight requests
    ride through unperturbed.
  * **persistence** — `save_all`/`load_all` park every overlay beside
    the base-table checkpoint shards so tenant memory survives restarts.

Semantics are property-tested against a pure-dict reference model in
`tests/test_overlay.py`.
"""

from __future__ import annotations

import os
from typing import Any, Callable

import numpy as np

from repro import quant

_META_KEYS = ("tenant", "storage", "layers", "last_used_tick", "writes")


def _safe(tenant_id: str) -> str:
    """Filesystem-safe tenant-id encoding (alnum/dash/underscore kept)."""
    return "".join(
        c if (c.isalnum() or c in "-_") else f"-{ord(c):02x}"
        for c in str(tenant_id)
    )


class TenantOverlay:
    """One tenant's sparse row view: per-layer ``row -> stored row`` in
    the base table's storage form, with insertion-ordered recency (a
    rewrite moves the row to newest; beyond ``max_rows`` the oldest row
    falls back to the base — copy-on-write in both directions)."""

    def __init__(self, tenant_id: str, *, num_layers: int, m: int,
                 storage: str = "fp32", max_rows: int = 64):
        if storage != "fp32":
            quant.check_kind(storage)
        self.tenant_id = tenant_id
        self.num_layers = num_layers
        self.m = m
        self.storage = storage
        self.max_rows = max_rows
        # layer -> {row_id: (payload (m,), scale | None)}; dict order is
        # recency (oldest first)
        self.rows: list[dict[int, tuple[np.ndarray, Any]]] = [
            {} for _ in range(num_layers)
        ]
        self.last_used_tick = 0
        self.writes = 0
        self.spilled_path: str | None = None

    # ------------------------------------------------------------ row ops

    def write(self, layer: int, row: int, values) -> None:
        """Store fp32 ``values`` as this tenant's row (storage-form
        round trip, same grid as the base table)."""
        d = self.rows[layer]
        d.pop(row, None)
        v = np.asarray(values, np.float32).reshape(self.m)
        if self.storage == "fp32":
            d[row] = (v.copy(), None)
        else:
            q, scale = quant.quantize_rows_np(v, self.storage)
            d[row] = (q, np.float32(scale))
        while len(d) > self.max_rows:
            d.pop(next(iter(d)))  # oldest falls back to the base row
        self.writes += 1

    def read(self, layer: int, row: int) -> np.ndarray | None:
        """Dequantized fp32 row, or None when the base row shows through."""
        entry = self.rows[layer].get(row)
        if entry is None:
            return None
        payload, scale = entry
        if scale is None:
            return payload.astype(np.float32)
        return quant.dequantize_rows_np(
            payload[None], np.asarray([scale], np.float32)
        )[0]

    def evict(self, layer: int, row: int) -> bool:
        return self.rows[layer].pop(row, None) is not None

    def clear(self) -> None:
        for d in self.rows:
            d.clear()

    def touch(self, tick: int) -> None:
        self.last_used_tick = max(self.last_used_tick, tick)

    @property
    def num_rows(self) -> int:
        return sum(len(d) for d in self.rows)

    @property
    def nbytes(self) -> int:
        kind = None if self.storage == "fp32" else self.storage
        return self.num_rows * quant.bytes_per_entry(self.m, kind)

    def packed_rows(self, layer: int) -> list[int]:
        """Row ids in recency order (oldest first) — at most max_rows, so
        a pack of that capacity always covers the whole overlay."""
        return list(self.rows[layer])

    # ------------------------------------------------------- persistence

    def save(self, path: str) -> None:
        """One ``.npz`` per tenant, storage-form payloads (fp8 riding as
        a uint8 view so npz needs no custom dtypes)."""
        arrays: dict[str, np.ndarray] = {
            "tenant": np.asarray(str(self.tenant_id)),
            "storage": np.asarray(self.storage),
            "layers": np.asarray(self.num_layers, np.int64),
            "last_used_tick": np.asarray(self.last_used_tick, np.int64),
            "writes": np.asarray(self.writes, np.int64),
        }
        for layer, d in enumerate(self.rows):
            ids = np.asarray(list(d), np.int64)
            arrays[f"ids{layer}"] = ids
            if not len(d):
                continue
            payload = np.stack([d[r][0] for r in d])
            if self.storage == "fp32":
                arrays[f"payload{layer}"] = payload
            else:
                arrays[f"payload{layer}"] = payload.view(np.uint8)
                arrays[f"scale{layer}"] = np.asarray(
                    [d[r][1] for r in d], np.float32
                )
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str, *, m: int) -> "TenantOverlay":
        with np.load(path) as z:
            ov = cls(
                str(z["tenant"]),
                num_layers=int(z["layers"]),
                m=m,
                storage=str(z["storage"]),
            )
            ov.last_used_tick = int(z["last_used_tick"])
            ov.writes = int(z["writes"])
            for layer in range(ov.num_layers):
                ids = z[f"ids{layer}"]
                if not len(ids):
                    continue
                payload = z[f"payload{layer}"]
                if ov.storage != "fp32":
                    payload = payload.view(quant.storage_dtype(ov.storage))
                    scales = z[f"scale{layer}"]
                    for i, r in enumerate(ids.tolist()):
                        ov.rows[layer][r] = (payload[i],
                                             np.float32(scales[i]))
                else:
                    for i, r in enumerate(ids.tolist()):
                        ov.rows[layer][r] = (
                            np.asarray(payload[i], np.float32), None
                        )
        return ov

    def restore_into(self, path: str) -> None:
        """Refill this (empty) overlay from a spill file in place."""
        loaded = TenantOverlay.load(path, m=self.m)
        if loaded.storage != self.storage:
            raise ValueError(
                f"overlay {self.tenant_id!r}: spill file stores "
                f"{loaded.storage}, manager expects {self.storage}"
            )
        self.rows = loaded.rows[:self.num_layers]
        while len(self.rows) < self.num_layers:
            self.rows.append({})
        self.last_used_tick = loaded.last_used_tick
        self.writes = loaded.writes


class OverlayManager:
    """Tenant registry + fixed-shape per-slot packs for `ServeEngine`.

    ``base_reader(layer, rows) -> (n, m) fp32`` is bound by the engine
    (and re-bound on `swap_model`, so a live dense->tiered migration
    keeps overlay deltas consistent with wherever the base rows live)."""

    def __init__(self, *, num_layers: int, m: int, storage: str,
                 slots: int, rows: int, write_lr: float = 0.1,
                 spill_dir: str | None = None):
        if rows < 1:
            raise ValueError("overlay needs at least one row per slot")
        self.num_layers = num_layers
        self.m = m
        self.storage = storage
        self.capacity = rows
        self.write_lr = float(write_lr)
        self.spill_dir = spill_dir
        self.overlays: dict[str, TenantOverlay] = {}
        self.slot_tenant: list[str | None] = [None] * slots
        # the packs the jitted steps read (repro.core.overlay): mutated
        # in place between ticks, never reshaped -> zero recompilation
        self.ids = np.full((num_layers, slots, rows), -1, np.int32)
        self.deltas = np.zeros((num_layers, slots, rows, m), np.float32)
        self.stats: dict[str, int] = dict.fromkeys(
            ("attaches", "detaches", "writebacks", "overlay_hits",
             "overlay_lookups", "spills", "restores", "drops"), 0,
        )
        self._base_reader: Callable[[int, np.ndarray], np.ndarray] | None \
            = None

    # ------------------------------------------------------------- wiring

    def set_base_reader(
        self, fn: Callable[[int, np.ndarray], np.ndarray]
    ) -> None:
        self._base_reader = fn
        for b, tid in enumerate(self.slot_tenant):
            if tid is not None:
                self._refresh_slot(b)

    def get(self, tenant_id: str) -> TenantOverlay:
        """The tenant's overlay, created empty (or restored from its
        spill file) on first touch."""
        ov = self.overlays.get(tenant_id)
        if ov is None:
            ov = TenantOverlay(
                tenant_id, num_layers=self.num_layers, m=self.m,
                storage=self.storage, max_rows=self.capacity,
            )
            self.overlays[tenant_id] = ov
        if ov.spilled_path is not None and ov.num_rows == 0:
            if os.path.exists(ov.spilled_path):
                ov.restore_into(ov.spilled_path)
                self.stats["restores"] += 1
            ov.spilled_path = None
        return ov

    # ------------------------------------------------------ attach/detach

    def attach(self, slot: int, tenant_id: str | None, *,
               tick: int = 0) -> None:
        """Bind a tenant to a decode slot (None = anonymous request:
        the slot serves the pristine base table)."""
        self.detach(slot)
        if tenant_id is None:
            return
        ov = self.get(tenant_id)
        ov.touch(tick)
        self.slot_tenant[slot] = tenant_id
        self.stats["attaches"] += 1
        self._refresh_slot(slot)

    def detach(self, slot: int) -> None:
        if self.slot_tenant[slot] is None:
            return
        self.slot_tenant[slot] = None
        self.stats["detaches"] += 1
        self.ids[:, slot, :] = -1
        self.deltas[:, slot, :, :] = 0.0

    @property
    def attached(self) -> int:
        return sum(1 for t in self.slot_tenant if t is not None)

    def _refresh_slot(self, slot: int) -> None:
        """Re-resolve one slot's pack from its tenant's overlay rows:
        ``delta = dequant(overlay_row) - base_row`` per packed id."""
        tid = self.slot_tenant[slot]
        self.ids[:, slot, :] = -1
        self.deltas[:, slot, :, :] = 0.0
        if tid is None or self._base_reader is None:
            return
        ov = self.overlays[tid]
        for layer in range(self.num_layers):
            packed = ov.packed_rows(layer)
            if not packed:
                continue
            row_ids = np.asarray(packed, np.int64)
            base = np.asarray(
                self._base_reader(layer, row_ids), np.float32
            ).reshape(len(packed), self.m)
            eff = np.stack([ov.read(layer, r) for r in packed])
            self.ids[layer, slot, :len(packed)] = row_ids
            self.deltas[layer, slot, :len(packed)] = eff - base

    # ---------------------------------------------------------- writeback

    def writeback(self, slot: int, idx, w, y, *, tick: int = 0) -> None:
        """Fold one decode tick's lattice accesses of `slot` into its
        tenant's overlay: Hebbian ``row += lr * Σ_{hk: idx=row} w · y_h``
        on the *effective* (overlay-before-base) row value.

        idx/w: (L, H, K); y: (L, H, m) — the post-scale per-head outputs
        collected by `repro.core.overlay`."""
        tid = self.slot_tenant[slot]
        if tid is None or self.write_lr == 0.0:
            return
        if self._base_reader is None:
            raise RuntimeError("OverlayManager has no base reader bound")
        ov = self.overlays[tid]
        idx = np.asarray(idx)
        w = np.asarray(w, np.float32)
        y = np.asarray(y, np.float32)
        for layer in range(self.num_layers):
            flat_r = idx[layer].reshape(-1)                  # (H*K,)
            top_k = idx[layer].shape[-1]
            contrib = (w[layer].reshape(-1)[:, None]
                       * np.repeat(y[layer], top_k, axis=0))  # (H*K, m)
            known = ov.rows[layer]
            self.stats["overlay_lookups"] += flat_r.size
            self.stats["overlay_hits"] += sum(
                1 for r in flat_r.tolist() if r in known
            )
            uniq, inv = np.unique(flat_r, return_inverse=True)
            agg = np.zeros((len(uniq), self.m), np.float32)
            np.add.at(agg, inv, contrib)
            base = np.asarray(
                self._base_reader(layer, uniq), np.float32
            ).reshape(len(uniq), self.m)
            for i, r in enumerate(uniq.tolist()):
                eff = ov.read(layer, r)
                if eff is None:
                    eff = base[i]                  # copy-on-write
                ov.write(layer, r, eff + self.write_lr * agg[i])
        ov.touch(tick)
        self.stats["writebacks"] += 1
        for b, t in enumerate(self.slot_tenant):
            if t == tid:
                self._refresh_slot(b)

    # ---------------------------------------------------------- lifecycle

    def total_bytes(self) -> int:
        return sum(ov.nbytes for ov in self.overlays.values())

    def enforce(self, *, tick: int, ttl_ticks: int | None = None,
                budget_bytes: int | None = None,
                spill_dir: str | None = None) -> list[dict[str, Any]]:
        """Apply TTL + byte-budget policy (called by
        `repro.memctl.MemoryController.on_tick`).  Only *detached*
        tenants are expired/spilled — in-flight requests never lose
        their overlay mid-generation.  Returns lifecycle events in the
        controller's telemetry schema."""
        spill_dir = spill_dir or self.spill_dir
        attached = {t for t in self.slot_tenant if t is not None}
        events = []
        if ttl_ticks is not None:
            for tid, ov in list(self.overlays.items()):
                if tid in attached or ov.num_rows == 0:
                    continue
                if tick - ov.last_used_tick >= ttl_ticks:
                    events.append(self._offload(
                        tid, tick, spill_dir, "overlay_expire"
                    ))
        if budget_bytes is not None and self.total_bytes() > budget_bytes:
            lru = sorted(
                (ov.last_used_tick, tid)
                for tid, ov in self.overlays.items()
                if tid not in attached and ov.num_rows > 0
            )
            for _, tid in lru:
                if self.total_bytes() <= budget_bytes:
                    break
                events.append(self._offload(
                    tid, tick, spill_dir, "overlay_spill"
                ))
        return events

    def _offload(self, tenant_id: str, tick: int, spill_dir: str | None,
                 event: str) -> dict[str, Any]:
        ov = self.overlays[tenant_id]
        nbytes = ov.nbytes
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
            path = os.path.join(
                spill_dir, f"overlay_{_safe(tenant_id)}.npz"
            )
            ov.save(path)
            ov.spilled_path = path
            self.stats["spills"] += 1
            action = "spill"
        else:
            self.stats["drops"] += 1
            action = "drop"
        ov.clear()
        return {"event": event, "tenant": tenant_id, "tick": tick,
                "bytes": nbytes, "action": action}

    # -------------------------------------------------------- persistence

    def save_all(self, dirpath: str) -> int:
        """Persist every non-empty overlay (one npz per tenant) beside
        the base-table checkpoint; returns the number written."""
        os.makedirs(dirpath, exist_ok=True)
        n = 0
        for tid, ov in self.overlays.items():
            if ov.spilled_path is not None and ov.num_rows == 0:
                self.get(tid)  # restore before persisting elsewhere
            if ov.num_rows == 0:
                continue
            ov.save(os.path.join(dirpath, f"overlay_{_safe(tid)}.npz"))
            n += 1
        return n

    def load_all(self, dirpath: str) -> int:
        """Register every persisted overlay found in `dirpath`."""
        if not os.path.isdir(dirpath):
            return 0
        n = 0
        for fn in sorted(os.listdir(dirpath)):
            if not (fn.startswith("overlay_") and fn.endswith(".npz")):
                continue
            ov = TenantOverlay.load(os.path.join(dirpath, fn), m=self.m)
            if ov.storage != self.storage:
                raise ValueError(
                    f"persisted overlay {ov.tenant_id!r} stores "
                    f"{ov.storage}, manager expects {self.storage}"
                )
            ov.max_rows = self.capacity
            for d in ov.rows:
                while len(d) > ov.max_rows:
                    d.pop(next(iter(d)))
            self.overlays[ov.tenant_id] = ov
            n += 1
        return n

    # ------------------------------------------------------------ reports

    def summary(self) -> dict[str, Any]:
        lookups = self.stats["overlay_lookups"]
        tenants = len(self.overlays)
        total = self.total_bytes()
        return {
            "tenants": tenants,
            "attached": self.attached,
            "rows": sum(ov.num_rows for ov in self.overlays.values()),
            "bytes": total,
            "bytes_per_tenant": round(total / tenants, 1) if tenants
            else 0.0,
            "hit_rate": round(self.stats["overlay_hits"] / lookups, 4)
            if lookups else 0.0,
            **self.stats,
        }
