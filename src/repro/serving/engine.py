"""Continuous-batching serve engine over fixed-shape decode slots.

The engine owns a slotted KV cache (`transformer.init_cache` with the batch
axis as a pool of `slots` sequences) and runs one jitted decode step per
tick, whatever mix of sequences is in flight:

  * **admit** — a ready request is prefilled at batch=1 (prompt padded up
    to a power-of-two bucket so the number of prefill compilations is
    O(log max_prompt), not O(#distinct lengths)) and its sub-cache spliced
    into a free slot (`transformer.write_cache_slot`).  Padded positions
    are harmless by construction: the decode step writes its KV row at the
    current position *before* attending, and the validity mask only ever
    exposes positions <= the slot's true depth, so a stale row is always
    overwritten before it can be read.
  * **step** — one fixed-shape `transformer.decode_step` with a per-slot
    position vector; retired/free slots ride along as maskable garbage
    (token 0 at their frozen position) and their outputs are dropped on
    the host.  No shape ever changes, so the step compiles exactly once.
  * **retire** — a slot whose request hits its generation budget (or the
    cache end) is marked free; the next admission overwrites every cache
    row, so retirement is O(1).

Scheduling modes share this loop and differ only in admission policy:

  * `continuous` — admit into any free slot, every tick.
  * `static`     — the legacy fixed-batch loop: admit only when *all*
    slots are free (gang admission), so a long sequence blocks the whole
    batch — the head-of-line blocking `benchmarks/table8_serving.py`
    quantifies.

Tiered memory integration: the engine asks the model's resolved lookup
plan (`repro.core.lookup.model_plans`) whether the placement
`supports_prefetch`; if so it collects the store handles (tiered or
sharded-tiered) and calls `prefetch_last()` after each tick — every
decode step covers the union of active sequences, so that prefetches
exactly the shards the union touched.  Per-request cache hit-rates are
attributed from per-tick stat deltas (shared-batch attribution: a tick's
hits count toward every request in flight during it).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import lookup
from repro.core import overlay as overlay_ctx
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving.overlay import OverlayManager
from repro.serving.requests import Request, RequestQueue

_STAT_KEYS = ("hits", "misses", "uncached")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine shape: pool size and per-slot sequence budget."""

    slots: int = 4
    max_len: int = 64           # per-slot cache length (prompt + generation)
    mode: str = "continuous"    # continuous | static (gang admission)
    # per-tenant memory overlays (repro.serving.overlay): capacity in
    # overlay rows per slot per lram layer; 0 disables the subsystem
    # entirely (the legacy jitted steps, byte-identical code paths)
    overlay_rows: int = 0
    overlay_write_lr: float = 0.1   # decode-step Hebbian writeback rate

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError("need at least one slot")
        if self.mode not in ("continuous", "static"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.overlay_rows < 0:
            raise ValueError("overlay_rows must be >= 0")


@dataclasses.dataclass
class _Slot:
    """Host-side state of one in-flight sequence."""

    request: Request
    pos: int                    # absolute position of the next decode write
    generated: list[int]
    admit_s: float
    prefill_s: float
    first_logits: np.ndarray    # (V,) logits of the first generated token
    stats: dict[str, int] = dataclasses.field(
        default_factory=lambda: dict.fromkeys(_STAT_KEYS, 0)
    )
    decode_steps: int = 0


def _bucket(n: int, cap: int) -> int:
    """Round a prompt length up to its power-of-two compile bucket."""
    return min(1 << (n - 1).bit_length(), cap)


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


@dataclasses.dataclass
class FinishedRequest:
    """Per-request serving record (the report's `requests` entries)."""

    id: int
    prompt_len: int
    tokens: list[int]
    admit_s: float
    finish_s: float
    prefill_s: float
    decode_steps: int
    cache_hit_rate: float | None
    first_logits: np.ndarray | None = None   # (V,) — equivalence testing

    def summary(self) -> dict[str, Any]:
        return {
            "id": self.id,
            "prompt_len": self.prompt_len,
            "generated": len(self.tokens),
            "admit_s": round(self.admit_s, 4),
            "finish_s": round(self.finish_s, 4),
            "latency_s": round(self.finish_s - self.admit_s, 4),
            "prefill_ms": round(1e3 * self.prefill_s, 3),
            "decode_steps": self.decode_steps,
            "cache_hit_rate": self.cache_hit_rate,
        }


@dataclasses.dataclass
class EngineReport:
    """Aggregate result of one trace replay."""

    mode: str
    wall_s: float
    generated_tokens: int
    step_s: list[float]
    prefill_s: list[float]
    requests: list[FinishedRequest]
    cache: dict[str, Any] | None
    overlay: dict[str, Any] | None = None   # OverlayManager.summary()

    @property
    def tokens_per_sec(self) -> float:
        return self.generated_tokens / self.wall_s if self.wall_s else 0.0

    def p50_ms(self) -> float:
        return 1e3 * _percentile(self.step_s, 50)

    def p99_ms(self) -> float:
        return 1e3 * _percentile(self.step_s, 99)

    def rows(self, prefix: str = "serve") -> list[list[Any]]:
        """Benchmark-harness rows: [name, us_per_call, derived]."""
        med_prefill = 1e6 * _percentile(self.prefill_s, 50)
        med_step = 1e6 * _percentile(self.step_s, 50)
        us_per_tok = (1e6 * self.wall_s / self.generated_tokens
                      if self.generated_tokens else 0.0)
        hit = (f"hit={self.cache['hit_rate']}" if self.cache else "dense")
        rows = [
            [f"{prefix}_prefill", round(med_prefill, 3),
             f"n={len(self.prefill_s)}"],
            [f"{prefix}_decode_step", round(med_step, 3),
             f"p50_ms={self.p50_ms():.3f} p99_ms={self.p99_ms():.3f} {hit}"],
            [f"{prefix}_token", round(us_per_tok, 3),
             f"tokens_per_sec={self.tokens_per_sec:.1f} "
             f"requests={len(self.requests)} mode={self.mode}"],
        ]
        if self.overlay:
            o = self.overlay
            rows.append([
                f"{prefix}_overlay", 0.0,
                f"tenants={o['tenants']} hit_rate={o['hit_rate']} "
                f"bytes_per_tenant={o['bytes_per_tenant']} "
                f"writebacks={o['writebacks']}",
            ])
        return rows

    def summary(self, arch: str) -> dict[str, Any]:
        """The `--json` summary document (schema shared with benchmarks)."""
        return {
            "arch": arch,
            "mode": self.mode,
            "metrics": obs.metrics_doc(),
            "rows": self.rows(),
            "per_step_ms": [round(1e3 * s, 3) for s in self.step_s],
            "decode_median_ms": round(1e3 * _percentile(self.step_s, 50), 2),
            "p50_ms": round(self.p50_ms(), 3),
            "p99_ms": round(self.p99_ms(), 3),
            "tokens_per_sec": round(self.tokens_per_sec, 2),
            "generated_tokens": self.generated_tokens,
            "cache": self.cache,
            "overlay": self.overlay,
            "requests": [r.summary() for r in self.requests],
        }


class ServeEngine:
    """Slot-pool serving engine (see module docstring for the lifecycle).

    `controller` (a `repro.memctl.MemoryController`) hooks in between
    decode ticks: when the memory table outgrows its HBM budget the
    controller migrates it to the tiered store and calls `swap_model`,
    which rebuilds the jitted steps around the new params while the slot
    pool and KV cache carry every in-flight request across the move.
    """

    def __init__(self, params, state, cfg: ModelConfig,
                 engine_cfg: EngineConfig, *, controller=None):
        if cfg.objective != "clm":
            raise ValueError("serving requires a causal-LM arch")
        if cfg.family in ("encdec", "vlm"):
            raise ValueError(
                f"continuous batching supports decoder-only families; "
                f"{cfg.name} is {cfg.family}"
            )
        self.state = state
        self.engine_cfg = engine_cfg
        self.controller = controller
        self.ticks = 0  # decode ticks since construction (policy clock)
        # per-tenant overlays: validated against the lookup plan's
        # capability flag, like prefetch — not isinstance probing
        self.overlays: OverlayManager | None = None
        if engine_cfg.overlay_rows > 0:
            plans = lookup.model_plans(cfg)
            if not plans:
                raise ValueError(
                    f"overlay_rows needs a memory arch; {cfg.name} has no "
                    f"LRAM layer"
                )
            if not plans[0].supports_overlay:
                raise ValueError(
                    f"lookup plan {plans[0]!r} does not support per-tenant "
                    f"overlays"
                )
            self.overlays = OverlayManager(
                num_layers=len(cfg.lram_layers), m=cfg.lram.m,
                storage=plans[0].storage, slots=engine_cfg.slots,
                rows=engine_cfg.overlay_rows,
                write_lr=engine_cfg.overlay_write_lr,
            )
        self._axes = transformer.cache_batch_axes(cfg, engine_cfg.max_len)
        self.cache = transformer.init_cache(
            cfg, engine_cfg.slots, engine_cfg.max_len
        )
        self.swap_model(params, cfg)

    def swap_model(self, params, cfg: ModelConfig | None = None) -> None:
        """(Re)bind the engine's jitted steps to `params` (and optionally a
        new model config — e.g. after a live dense→tiered migration).

        Slot state and the KV cache are untouched: the decode-slot shapes
        depend only on the engine config, so in-flight requests resume on
        the very next tick.  The swapped-in steps compile on first use
        (one-time pause, the cost `benchmarks/table10_lifecycle.py`
        reports as migration pause time)."""
        self.params = params
        if cfg is not None:
            self.cfg = cfg
        cfg = self.cfg
        state = self.state
        # prefetch handles come from the lookup plan's capability flags
        # (tiered and sharded-tiered placements), not from isinstance
        # probing of params
        self.stores = (
            lookup.find_stores(params)
            if any(p.supports_prefetch for p in lookup.model_plans(cfg))
            else []
        )
        # CPU has no buffer donation; donating there only logs warnings
        donate = () if jax.default_backend() == "cpu" else (2,)
        if self.overlays is None:
            self._decode = jax.jit(
                lambda tok, pos, cache: transformer.decode_step(
                    params, state, tok, pos, cache, cfg
                ),
                donate_argnums=donate,
            )
            # jit specializes per tokens shape, so bucketing alone bounds
            # the number of prefill compilations
            self._prefill = jax.jit(
                lambda tokens: transformer.prefill(
                    params, state, {"tokens": tokens}, cfg,
                    self.engine_cfg.max_len
                )
            )
        else:
            # the overlay context wraps the model call *inside* jit: the
            # packs are traced arguments with fixed shapes, so slot
            # attach/detach only mutates host arrays — the decode step
            # still compiles exactly once.  Pack args ride behind the
            # cache, keeping donate_argnums=(2,) valid.
            def _decode_fn(tok, pos, cache, ids, deltas):
                with overlay_ctx.activate(
                    ids, deltas, collect=True
                ) as octx:
                    logits, new_cache = transformer.decode_step(
                        params, state, tok, pos, cache, cfg
                    )
                    access = octx.stacked()
                return logits, new_cache, access

            def _prefill_fn(tokens, ids, deltas):
                with overlay_ctx.activate(ids, deltas):
                    return transformer.prefill(
                        params, state, {"tokens": tokens}, cfg,
                        self.engine_cfg.max_len
                    )

            self._decode = jax.jit(_decode_fn, donate_argnums=donate)
            self._prefill = jax.jit(_prefill_fn)
            self._bind_overlay_reader()
        self._write_slot = jax.jit(
            lambda cache, sub, slot: transformer.write_cache_slot(
                cache, sub, slot, self._axes
            ),
            donate_argnums=() if not donate else (0,),
        )

    def _bind_overlay_reader(self) -> None:
        """Point the overlay manager at the current params' base tables
        (re-bound on every swap_model, so live migrations keep overlay
        deltas consistent with wherever the rows now live)."""
        cfg, params = self.cfg, self.params
        tables = []
        for si, seg in enumerate(transformer.layer_plan(cfg)):
            if seg[0] == "memory" and seg[2] == "lram":
                tables.append(
                    params["segments"][f"seg{si}"]["memffn"]["lram"]["values"]
                )
        host: dict[int, Any] = {}  # device tables snapshot once per swap

        def read(layer: int, rows) -> np.ndarray:
            table = tables[layer]
            rows = np.asarray(rows, np.int64).reshape(-1)
            if lookup.is_store(table):
                return lookup.read_rows_fp32(table, rows)
            cached = host.get(layer)
            if cached is None:
                from repro.quant import QuantizedTable

                if isinstance(table, QuantizedTable):
                    cached = (np.asarray(table.q),
                              np.asarray(table.scale, np.float32))
                else:
                    cached = np.asarray(table, np.float32)
                host[layer] = cached
            if isinstance(cached, tuple):
                from repro import quant

                return quant.dequantize_rows_np(
                    cached[0][rows], cached[1][rows]
                )
            return cached[rows]

        self.overlays.set_base_reader(read)

    # ------------------------------------------------------------ internals

    def _store_stats(self) -> dict[str, int]:
        out = dict.fromkeys(_STAT_KEYS, 0)
        for _, store in self.stores:
            for k in _STAT_KEYS:
                out[k] += store.stats[k]
        return out

    def _admit(self, req: Request, now: float,
               slot_index: int) -> tuple[_Slot, Any]:
        """Prefill one request and splice it into the slotted cache."""
        s = req.prompt_len
        budget = self.engine_cfg.max_len - s
        if budget < 1:
            raise ValueError(
                f"request {req.id}: prompt ({s}) leaves no room to "
                f"generate within max_len={self.engine_cfg.max_len}"
            )
        # attention masks padded positions out (and decode overwrites their
        # KV rows before they can be read), so prompts bucket to powers of
        # two.  Two families must prefill at exact length instead (one
        # compile per distinct length): recurrent state integrates every
        # position, and an SWA ring buffer keeps the *last* window positions
        # of the padded prompt — all valid the moment the ring is full, so
        # pad rows there are not maskable either.
        if self.cfg.family in ("ssm", "hybrid") or self.cfg.attention == "swa":
            bucket = s
        else:
            bucket = _bucket(s, self.engine_cfg.max_len)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :s] = req.prompt
        t0 = time.perf_counter()
        with obs.span("serve.prefill", request=req.id, prompt_len=s,
                      bucket=bucket):
            if self.overlays is None:
                logits, sub_cache = self._prefill(jnp.asarray(tokens))
            else:
                # bind the request's tenant before prefill so the prompt
                # already reads through the tenant's overlay rows; the
                # batch=1 pack slice has a constant shape across slots
                self.overlays.attach(slot_index, req.tenant_id,
                                     tick=self.ticks)
                b = slot_index
                logits, sub_cache = self._prefill(
                    jnp.asarray(tokens),
                    jnp.asarray(self.overlays.ids[:, b:b + 1]),
                    jnp.asarray(self.overlays.deltas[:, b:b + 1]),
                )
            first_logits = np.asarray(logits[0, s - 1])
        prefill_s = time.perf_counter() - t0
        obs.counter("serve.admitted").inc()
        obs.histogram("serve.prefill_s").observe(prefill_s)
        first_tok = int(np.argmax(first_logits))
        return _Slot(
            request=req, pos=s, generated=[first_tok], admit_s=now,
            prefill_s=prefill_s, first_logits=first_logits,
        ), sub_cache

    def _finish(self, slot: _Slot, now: float) -> FinishedRequest:
        st = slot.stats
        total = sum(st.values())
        obs.counter("serve.retired").inc()
        obs.histogram("serve.request_latency_s").observe(now - slot.admit_s)
        return FinishedRequest(
            id=slot.request.id,
            prompt_len=slot.request.prompt_len,
            tokens=slot.generated,
            admit_s=slot.admit_s,
            finish_s=now,
            prefill_s=slot.prefill_s,
            decode_steps=slot.decode_steps,
            cache_hit_rate=(round(st["hits"] / total, 4)
                            if self.stores and total else
                            (0.0 if self.stores else None)),
            first_logits=slot.first_logits,
        )

    def _done(self, slot: _Slot) -> bool:
        return (len(slot.generated) >= slot.request.max_new_tokens
                or slot.pos >= self.engine_cfg.max_len)

    # ------------------------------------------------------------- run loop

    def run(self, requests: list[Request]) -> EngineReport:
        """Replay a request trace to completion and report.

        The whole replay runs under a `serve.run` span (marked for
        `jax.profiler` capture when `--profile-dir` armed the tracer);
        each admission opens `serve.admit` > `serve.prefill` and each
        pool-wide step a `serve.decode_tick` span, so an exported trace
        shows per-tick wall time with the store fills/hits that tick
        caused attached as counter deltas."""
        with obs.span("serve.run", profile=True,
                      mode=self.engine_cfg.mode, requests=len(requests)):
            return self._run(requests)

    def _run(self, requests: list[Request]) -> EngineReport:
        B = self.engine_cfg.slots
        static = self.engine_cfg.mode == "static"
        queue = RequestQueue(requests)
        for _, store in self.stores:
            store.warm()
            store.reset_stats()
        slots: list[_Slot | None] = [None] * B
        tok_buf = np.zeros((B, 1), np.int32)
        pos_buf = np.zeros((B,), np.int32)
        step_s: list[float] = []
        prefill_s: list[float] = []
        finished: list[FinishedRequest] = []
        generated = 0
        t0 = time.perf_counter()
        now = 0.0
        prev_stats = self._store_stats()

        while True:
            now = time.perf_counter() - t0
            # -- admission (static mode gates on a fully drained pool)
            if not static or all(sl is None for sl in slots):
                for b in range(B):
                    if slots[b] is not None:
                        continue
                    req = queue.pop_ready(now)
                    if req is None:
                        break
                    with obs.span("serve.admit", request=req.id,
                                  slot=b, tick=self.ticks):
                        slot, sub_cache = self._admit(req, now, b)
                        self.cache = self._write_slot(
                            self.cache, sub_cache, jnp.int32(b)
                        )
                    prefill_s.append(slot.prefill_s)
                    generated += 1  # first token comes from the prefill
                    # prefill stat delta belongs to the admitted request
                    cur = self._store_stats()
                    for k in _STAT_KEYS:
                        slot.stats[k] += cur[k] - prev_stats[k]
                    prev_stats = cur
                    now = time.perf_counter() - t0
                    if self._done(slot):  # 1-token budget: no decode steps
                        finished.append(self._finish(slot, now))
                        if self.overlays is not None:
                            self.overlays.detach(b)
                        continue
                    slots[b] = slot
                    tok_buf[b, 0] = slot.generated[-1]
                    pos_buf[b] = slot.pos

            active = [b for b in range(B) if slots[b] is not None]
            if not active:
                nxt = queue.next_arrival()
                if nxt is None:
                    break  # drained
                time.sleep(max(0.0, nxt - (time.perf_counter() - t0)))
                continue

            # -- one fixed-shape decode tick over the whole pool
            t_step = time.perf_counter()
            with obs.span("serve.decode_tick", tick=self.ticks,
                          active=len(active)):
                if self.overlays is None:
                    logits, self.cache = self._decode(
                        jnp.asarray(tok_buf), jnp.asarray(pos_buf),
                        self.cache
                    )
                    access = None
                else:
                    logits, self.cache, access = self._decode(
                        jnp.asarray(tok_buf), jnp.asarray(pos_buf),
                        self.cache,
                        jnp.asarray(self.overlays.ids),
                        jnp.asarray(self.overlays.deltas),
                    )
                next_tok = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            dt_step = time.perf_counter() - t_step
            step_s.append(dt_step)
            obs.histogram("serve.decode_step_s").observe(dt_step)
            obs.counter("serve.tokens").inc(len(active))
            self.ticks += 1

            # decode-step writeback: fold this tick's lattice accesses
            # into each active slot's tenant overlay (the packs refresh
            # in place, taking effect from the next tick)
            if access is not None:
                idx_a, w_a, y_a = (np.asarray(a) for a in access)
                for b in active:
                    self.overlays.writeback(
                        b, idx_a[:, b, 0], w_a[:, b, 0], y_a[:, b, 0],
                        tick=self.ticks,
                    )
                obs.counter("serve.overlay_writebacks").inc(len(active))

            # per-request attribution of this tick's cache-stat deltas
            if self.stores:
                cur = self._store_stats()
                for b in active:
                    for k in _STAT_KEYS:
                        slots[b].stats[k] += cur[k] - prev_stats[k]
                prev_stats = cur
                # prefetch the union of active sequences' accesses so the
                # fill overlaps the next tick's dense compute
                for _, store in self.stores:
                    store.prefetch_last()

            # lifecycle hook: the controller may swap the model between
            # ticks (e.g. spill a dense table that outgrew HBM to the
            # tiered store); in-flight slots ride through untouched
            if self.controller is not None and self.controller.on_tick(self):
                prev_stats = self._store_stats()

            now = time.perf_counter() - t0
            for b in active:
                sl = slots[b]
                sl.generated.append(int(next_tok[b]))
                sl.pos += 1
                sl.decode_steps += 1
                generated += 1
                tok_buf[b, 0] = int(next_tok[b])
                pos_buf[b] = sl.pos
                if self._done(sl):
                    with obs.span("serve.retire", request=sl.request.id,
                                  slot=b, tick=self.ticks):
                        finished.append(self._finish(sl, now))
                        slots[b] = None
                        if self.overlays is not None:
                            # retire frees the overlay
                            self.overlays.detach(b)

        wall = time.perf_counter() - t0
        cache_summary = None
        if self.stores:
            agg = {k: 0 for k in
                   ("hits", "misses", "uncached", "fills", "evictions")}
            for _, store in self.stores:
                for k in agg:
                    agg[k] += store.stats[k]
            cache_summary = {
                "hit_rate": round(float(np.mean(
                    [s.hit_rate() for _, s in self.stores]
                )), 4),
                **agg,
            }
        finished.sort(key=lambda r: r.id)
        return EngineReport(
            mode=self.engine_cfg.mode,
            wall_s=wall,
            generated_tokens=generated,
            step_s=step_s,
            prefill_s=prefill_s,
            requests=finished,
            cache=cache_summary,
            overlay=(self.overlays.summary()
                     if self.overlays is not None else None),
        )


def serve_requests(params, state, cfg: ModelConfig, requests: list[Request],
                   *, slots: int = 4, max_len: int | None = None,
                   mode: str = "continuous") -> EngineReport:
    """One-shot convenience: build an engine sized for `requests`, run it."""
    if max_len is None:
        max_len = max(r.prompt_len + r.max_new_tokens for r in requests)
    engine = ServeEngine(
        params, state, cfg,
        EngineConfig(slots=slots, max_len=max_len, mode=mode),
    )
    return engine.run(requests)
