"""Continuous-batching serve subsystem.

Public surface:

  * `Request`, `RequestQueue`, `synthetic_trace` — request/trace model
    (arrival-time simulation + the real-entrypoint queue hook).
  * `EngineConfig`, `ServeEngine`, `serve_requests` — the slot-pool engine:
    fixed-shape decode slots, per-tick admit/retire without recompilation,
    batch=1 bucketed prefill spliced into the slotted KV cache, tiered
    memstore prefetch driven by the union of in-flight sequences.
  * `EngineReport`, `FinishedRequest` — machine-readable results
    (`EngineReport.summary()` is the `launch.serve --json` document;
    `.rows()` is the benchmark-harness row format).
  * `TenantOverlay`, `OverlayManager` — per-tenant copy-on-write memory
    overlays over the shared base table (docs/serving.md): attached at
    admission, written back every decode tick, retired with the slot.

`repro.launch.serve` is the CLI over this package; design narrative in
docs/serving.md.
"""

from repro.serving.engine import (
    EngineConfig,
    EngineReport,
    FinishedRequest,
    ServeEngine,
    serve_requests,
)
from repro.serving.overlay import OverlayManager, TenantOverlay
from repro.serving.requests import Request, RequestQueue, synthetic_trace

__all__ = [
    "EngineConfig",
    "EngineReport",
    "FinishedRequest",
    "OverlayManager",
    "Request",
    "RequestQueue",
    "ServeEngine",
    "TenantOverlay",
    "serve_requests",
    "synthetic_trace",
]
