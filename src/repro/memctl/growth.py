"""Online capacity growth: enlarge a live value table, append-only.

The paper's headline claim — "continued scaling with memory size up to
the limits tested" — makes capacity the axis worth changing *mid-run*.
Growth here is a warm start derived from the lattice structure
(`repro.core.indexing`):

1. **The torus grows index-preservingly.**  `grow_torus` multiplies the
   wrap length K_0 by the (power-of-two) growth factor.  K_0's mixed-radix
   digit carries no weight in `encode_points`, so every lattice point of
   the old fundamental box keeps its exact flat index, and the new points
   get indices in `[old_N, new_N)` — growth is an append, never a
   permutation.
2. **New rows copy their nearest coarse-lattice parent.**  A new point,
   wrapped onto the *old* torus, lands on the old lattice point that
   served its queries before growth (`growth_parents`; for `grow_torus`
   enlargements the mapping reduces to `j mod old_N`).  Copying the
   parent's row makes pre-growth lookups reproduce **bit-exactly** for
   every storage kind: fp32 rows copy, quantized rows copy payload +
   per-row scale (no requantization error).  Post-growth training then
   diverges the aliases apart — that is the utilisation-recovery curve
   `benchmarks/table10_lifecycle.py` measures.
3. **Each placement grows in its own layout.**  Dense tables (and
   `QuantizedTable` payload+scale) concatenate on device; tiered stores
   append host shards without touching the device cache
   (`TieredValueStore.grow_rows`); sharded-tiered stores append whole row
   ranges (`ShardedTieredStore.grow_rows`).  Mesh-sharded dense tables
   (`interp_impl="sharded"`) report `supports_growth=False` — reshard by
   relaunch, or migrate to sharded-tiered first.

`grow_model` applies the same step across a full model tree (every
`lram/values` leaf plus its Adam moments, so the optimizer warm-starts
too) and returns the updated `ModelConfig` — re-jit the train/decode step
against it, nothing else changes.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import indexing, lookup
from repro.quant import QuantizedTable


def _growth_factor(old_n: int, new_num_rows: int) -> int:
    if new_num_rows <= old_n or new_num_rows % old_n:
        raise ValueError(
            f"can only grow to a multiple of the current size: "
            f"{old_n} -> {new_num_rows}"
        )
    factor = new_num_rows // old_n
    if factor & (factor - 1):
        raise ValueError(
            f"growth factor must be a power of two, got {factor}"
        )
    return factor


def grown_cfg(cfg, new_num_rows: int):
    """The LRAMConfig after growing to `new_num_rows`: log2_locations
    bumped, the explicit (index-preserving) torus attached, and — for
    sharded-tiered placements — `model_shards` scaled with the appended
    ranges."""
    factor = _growth_factor(cfg.num_locations, new_num_rows)
    new_spec = indexing.grow_torus(cfg.torus_spec, factor)
    kw: dict[str, Any] = {
        "log2_locations": cfg.log2_locations + factor.bit_length() - 1,
        "torus": new_spec,
    }
    if cfg.interp_impl == "sharded-tiered":
        ranges = cfg.model_shards
        if ranges <= 0:
            from repro.distributed import context as _ctx
            from repro.distributed.sharded_lram import AXIS

            mesh = _ctx.get_mesh()
            ranges = (mesh.shape[AXIS]
                      if mesh is not None and AXIS in mesh.axis_names else 1)
        kw["model_shards"] = ranges * factor
    return dataclasses.replace(cfg, **kw)


def _grow_array(x, parents):
    idx = jnp.asarray(parents, jnp.int32)
    return jnp.concatenate([x, jnp.take(x, idx, axis=0)], axis=0)


def _grow_table(table, new_num_rows: int, parents: np.ndarray,
                seen: set[int]):
    """Grow one table object (dense array, QuantizedTable, or store).
    `seen` guards store nodes shared across tree positions (params +
    optimizer moments hold the same object) from growing twice."""
    if lookup.is_store(table):
        if id(table) not in seen:
            seen.add(id(table))
            table.grow_rows(new_num_rows, parents)
        return table
    if isinstance(table, QuantizedTable):
        # payload + per-row scale copy: bit-exact, no requantization
        return QuantizedTable(
            q=_grow_array(table.q, parents),
            scale=_grow_array(table.scale, parents),
            kind=table.kind,
        )
    return _grow_array(table, parents)


def grow(params, cfg, new_num_rows: int):
    """Grow one LRAM layer's value table in place: returns
    `(new_params, new_cfg)`.

    `params` is the layer's param dict (`{"values": ..., "qnorm": ...}`).
    Dense tables come back as new (longer) arrays; store tables mutate in
    place and keep their identity, so serve-engine and trainer handles
    stay valid.  Query-norm parameters are per-feature and untouched.
    """
    plan = lookup.resolve(cfg)
    if not plan.supports_growth:
        raise lookup.LookupPlanError(
            plan.placement, plan.storage, plan.kernel,
            "placement cannot grow live (mesh-sharded dense tables "
            "reshard by relaunch, or migrate to sharded-tiered first)",
        )
    old_n = cfg.num_locations
    new_cfg = grown_cfg(cfg, new_num_rows)
    parents = indexing.growth_parents(
        cfg.torus_spec, new_cfg.torus_spec, old_n, new_num_rows
    )
    new_params = dict(params)
    new_params["values"] = _grow_table(
        params["values"], new_num_rows, parents, set()
    )
    return new_params, new_cfg


def _grow_tree(tree, new_num_rows: int, parents, seen):
    """Grow every `lram/values` leaf in a model-sized pytree (params, or
    an optimizer-moment tree mirroring params)."""
    return lookup.map_memory_tables(
        tree, lambda t: _grow_table(t, new_num_rows, parents, seen)
    )


def grow_model(params, model_cfg, new_num_rows: int, *, opt_state=None):
    """Grow every memory layer of a model to `new_num_rows` locations.

    Returns `(params, model_cfg, opt_state)` — `opt_state` is passed
    through untouched when None.  Adam moments for dense tables grow by
    the same parent copy (the alias rows inherit their parent's gradient
    statistics: a warm start, matching the values themselves); stores are
    leafless in the moment trees and shared with params, so the identity
    guard keeps them from growing twice.
    """
    if model_cfg.lram is None or not model_cfg.lram_layers:
        raise ValueError(f"{model_cfg.name} has no LRAM memory layer")
    lram_cfg = model_cfg.lram
    plan = lookup.resolve(lram_cfg)
    if not plan.supports_growth:
        raise lookup.LookupPlanError(
            plan.placement, plan.storage, plan.kernel,
            "placement cannot grow live",
        )
    old_n = lram_cfg.num_locations
    new_lram = grown_cfg(lram_cfg, new_num_rows)
    parents = indexing.growth_parents(
        lram_cfg.torus_spec, new_lram.torus_spec, old_n, new_num_rows
    )
    seen: set[int] = set()
    params = _grow_tree(params, new_num_rows, parents, seen)
    if opt_state is not None:
        opt_state = dict(opt_state)
        for key in ("mu", "nu"):
            if key in opt_state:
                opt_state[key] = _grow_tree(
                    opt_state[key], new_num_rows, parents, seen
                )
    new_model_cfg = dataclasses.replace(model_cfg, lram=new_lram)
    return params, new_model_cfg, opt_state
