"""Memory lifecycle manager: telemetry, online growth, live migration.

The lookup-plan registry (`repro.core.lookup`) froze the memory layer's
shape at construction: capacity, placement, and storage were fixed the
moment `resolve` ran.  This package is the lifecycle layer above it —
everything that changes a *running* model's memory without a restart:

* `telemetry` — jit-safe per-row access counters (segment-sum over lookup
  indices, carried like optimizer state) and store-side per-shard
  counters, aggregated into hot/cold/dead utilisation reports.
* `growth` — `grow` / `grow_model`: enlarge the value table in place.
  Append-only by construction (`indexing.grow_torus` doubles the torus'
  K_0, which preserves every old flat index); new rows warm-start from
  their nearest coarse-lattice parent, so pre-growth lookups reproduce
  bit-exactly for every storage kind.
* `migrate` — `migrate` / `migrate_model`: convert a live model between
  placement cells (dense ↔ tiered ↔ sharded-tiered, any storage pair) by
  streaming the byte-compatible checkpoint shard layout in memory —
  same-storage migrations are payload-exact.
* `controller` — `MemoryController`: the policy loop the trainer calls on
  a step schedule (`launch/train.py --grow-at`) and the serve engine
  calls between decode ticks (HBM-budget spill of a dense table to the
  tiered store without dropping in-flight requests).

See docs/lifecycle.md for the design narrative, the growth math, the
migration matrix, and pause-time expectations.
"""

from repro.memctl.controller import (  # noqa: F401
    LifecyclePolicy,
    MemoryController,
    parse_grow_at,
)
from repro.memctl.growth import grow, grow_model, grown_cfg  # noqa: F401
from repro.memctl.migrate import migrate, migrate_model  # noqa: F401
from repro.memctl.telemetry import (  # noqa: F401
    grow_telemetry,
    store_telemetry,
    telemetry_init,
    telemetry_update,
    utilisation_report,
    utilisation_summary,
)
