"""Live plan-to-plan migration: move a value table between placement ×
storage cells without a restart.

The checkpoint manager already defines a byte-level lingua franca for
memory tables: a stream of `(payload shard, per-row scales)` pairs with
global shard ids, convertible between dense / tiered / sharded-tiered and
any storage kind (`TieredValueStore.load_shard`).  Migration reuses that
layout **in memory**: the source table is read in storage form (1-byte
payload + scales for quantized tables, fp rows otherwise) and streamed
into a freshly built target of the destination plan's layout
(`LookupPlan.build_empty` for store placements).

Exactness contract:

* same-storage migrations are **payload-exact** — the bytes move, nothing
  is requantized, so a round-trip dense → tiered → sharded-tiered → dense
  reproduces logits exactly;
* quantized → fp32 dequantizes exactly (fp32 product of payload and
  scale); fp32 → quantized rounds to nearest, within
  `repro.quant.max_abs_error_bound`;
* cross-kind quantized pairs requantize through fp32 (the same path a
  cross-kind checkpoint restore takes).

Mesh-sharded dense placements (`interp_impl="sharded"`) are excluded:
their table lives as partitioned device buffers owned by the mesh, and
moving it is a resharding relaunch, not a live migration.

`migrate_model` swaps every `lram/values` leaf and returns the updated
`ModelConfig`; the serve engine applies it between decode ticks via
`MemoryController` + `ServeEngine.swap_model`, so in-flight requests keep
their slots and KV cache across the move.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.core import lookup
from repro.quant import QuantizedTable


def _read_rows(table, lo: int, hi: int):
    """(payload, scales|None) rows [lo, hi) of any table type, in storage
    form — the in-memory analogue of reading a checkpoint shard."""
    if lookup.is_store(table):
        return table._read_rows_raw(np.arange(lo, hi, dtype=np.int64))
    if isinstance(table, QuantizedTable):
        return (np.asarray(table.q[lo:hi]),
                np.asarray(table.scale[lo:hi], np.float32))
    return np.asarray(table[lo:hi]), None


def _to_fp32(payload: np.ndarray, scales) -> np.ndarray:
    if scales is None:
        return np.asarray(payload, np.float32)
    return quant.dequantize_rows_np(payload, scales)


def migrate_table(table, src_cfg, dst_cfg):
    """Build `dst_cfg`'s table from `table` (laid out per `src_cfg`)."""
    src_plan = lookup.resolve(src_cfg)
    dst_plan = lookup.resolve(dst_cfg)
    for plan in (src_plan, dst_plan):
        if plan.requires_mesh:
            raise lookup.LookupPlanError(
                plan.placement, plan.storage, plan.kernel,
                "mesh-sharded dense tables do not migrate live — reshard "
                "by relaunch, or use the sharded-tiered placement",
            )
    if (src_cfg.num_locations != dst_cfg.num_locations
            or src_cfg.m != dst_cfg.m):
        raise ValueError(
            f"migration cannot change the table shape: "
            f"{src_cfg.num_locations}x{src_cfg.m} -> "
            f"{dst_cfg.num_locations}x{dst_cfg.m} (grow first)"
        )
    n = src_cfg.num_locations

    if dst_plan.build_empty is not None:  # store target: stream shards
        dst = dst_plan.build_empty()
        rows = dst.shard_rows
        for i in range(dst.num_shards):
            payload, scales = _read_rows(table, i * rows, (i + 1) * rows)
            # load_shard converts: same-kind passes bytes through (exact),
            # fp input quantizes nearest, cross-kind requantizes
            dst.load_shard(i, payload, scales)
        if lookup.is_store(table):
            dst.writeback_lr = table.writeback_lr
        return dst

    payload, scales = _read_rows(table, 0, n)
    if dst_plan.storage == "fp32":
        return jnp.asarray(_to_fp32(payload, scales))
    if scales is not None \
            and payload.dtype == quant.storage_dtype(dst_plan.storage):
        return QuantizedTable(  # same-kind: payload-exact
            q=jnp.asarray(payload), scale=jnp.asarray(scales),
            kind=dst_plan.storage,
        )
    q, s = quant.quantize_rows_np(_to_fp32(payload, scales),
                                  dst_plan.storage)
    return QuantizedTable(q=jnp.asarray(q), scale=jnp.asarray(s),
                          kind=dst_plan.storage)


def migrate(params, src_cfg, dst_cfg):
    """Migrate one LRAM layer's param dict: returns new params (the
    query-norm leaves are placement-independent and shared)."""
    new_params = dict(params)
    new_params["values"] = migrate_table(params["values"], src_cfg, dst_cfg)
    return new_params


def migrate_model(params, model_cfg, dst_lram_cfg):
    """Migrate every memory layer of a model to `dst_lram_cfg`'s cell.

    Returns `(params, model_cfg)` with `model_cfg.lram` replaced.  Tables
    shared across tree positions migrate once (identity-mapped).
    """
    if model_cfg.lram is None or not model_cfg.lram_layers:
        raise ValueError(f"{model_cfg.name} has no LRAM memory layer")
    src_cfg = model_cfg.lram
    done: dict[int, object] = {}  # tables shared across paths migrate once

    def _migrate_leaf(table):
        if id(table) not in done:
            done[id(table)] = migrate_table(table, src_cfg, dst_lram_cfg)
        return done[id(table)]

    new_params = lookup.map_memory_tables(params, _migrate_leaf)
    return new_params, dataclasses.replace(model_cfg, lram=dst_lram_cfg)
