"""Usage telemetry: who reads which memory rows, and how recently.

Large Memory Layers with Product Keys (Lample et al., 2019) track
key-usage statistics because a memory whose rows go *dead* stops earning
its parameter budget — and Memory Layers at Scale (Berges et al., 2024)
grows capacity as the dominant scaling axis, which only pays off if the
grown rows come alive.  This module is the measurement side of that loop:

* **In-graph counters** (`telemetry_init` / `telemetry_update`): a pytree
  of per-bin hit counts plus an exponential moving average, updated by a
  jit-safe segment-sum (scatter-add) over the lookup's index tensor.  The
  pytree rides alongside optimizer state — carry it through the train
  step like any other per-step accumulator.  `rows_per_bin` coarsens the
  resolution for tables too large for per-row counters.
* **Store-side counters** (`store_telemetry`): tiered and sharded-tiered
  placements already walk every access host-side, so their stores count
  per-shard hits for free (`TieredValueStore.row_stats`, aggregated
  range-major by `ShardedTieredStore.row_stats` — plans with
  `row_stats=True`).  One bin per host shard.
* **Reports** (`utilisation_report`): hot/cold/dead bin fractions in the
  benchmark row schema (`[name, us_per_call, derived]` — the same triples
  `benchmarks/run.py` and the serve `--json` summary emit), so lifecycle
  health drops into the existing tooling unchanged.

`grow_telemetry` mirrors `memctl.grow`: appended rows start as fresh
(dead) bins, which is exactly what the post-growth recovery curve in
`benchmarks/table10_lifecycle.py` watches.
"""

from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

Telemetry = dict[str, Any]


def telemetry_init(num_rows: int, *, rows_per_bin: int = 1) -> Telemetry:
    """Zeroed counters for a table of `num_rows`, one bin per
    `rows_per_bin` consecutive rows (must divide `num_rows`)."""
    if num_rows % rows_per_bin:
        raise ValueError(
            f"rows_per_bin={rows_per_bin} must divide num_rows={num_rows}"
        )
    bins = num_rows // rows_per_bin
    return {
        "counts": jnp.zeros(bins, jnp.float32),
        "ema": jnp.zeros(bins, jnp.float32),
        "steps": jnp.zeros((), jnp.int32),
        "rows_per_bin": jnp.asarray(rows_per_bin, jnp.int32),
    }


def telemetry_update(tel: Telemetry, idx, *, decay: float = 0.95) -> Telemetry:
    """One observation step: scatter-add the lookup's index tensor.

    Pure and jit-safe (the segment-sum is a single `.at[].add`), so it can
    live inside the jitted train step with `tel` as a carried pytree —
    the optimizer-state pattern.  `idx` is any integer tensor of flat row
    ids (e.g. the `(..., top_k)` access tensor from
    `lram_apply(..., return_access=True)`).
    """
    flat = jnp.reshape(jnp.asarray(idx), (-1,)).astype(jnp.int32)
    flat = flat // tel["rows_per_bin"]
    hits = jnp.zeros_like(tel["counts"]).at[flat].add(1.0)
    return {
        "counts": tel["counts"] + hits,
        "ema": decay * tel["ema"] + (1.0 - decay) * hits,
        "steps": tel["steps"] + 1,
        "rows_per_bin": tel["rows_per_bin"],
    }


def store_telemetry(store) -> Telemetry:
    """Telemetry snapshot from a store's own per-shard counters (plans
    with `row_stats=True`).  Host-side lifetime counts: `ema` mirrors
    `counts` (the store tracks no decay), `steps` is the lookup count."""
    counts, rows_per_bin = store.row_stats()
    counts = jnp.asarray(np.asarray(counts, np.float32))
    return {
        "counts": counts,
        "ema": counts,
        "steps": jnp.asarray(int(store.stats["lookups"]), jnp.int32),
        "rows_per_bin": jnp.asarray(rows_per_bin, jnp.int32),
    }


def grow_telemetry(tel: Telemetry, new_num_rows: int) -> Telemetry:
    """Extend counters for a grown table: appended rows start dead."""
    rpb = int(tel["rows_per_bin"])
    if new_num_rows % rpb:
        raise ValueError(
            f"new_num_rows={new_num_rows} not divisible by "
            f"rows_per_bin={rpb}"
        )
    extra = new_num_rows // rpb - tel["counts"].shape[0]
    if extra < 0:
        raise ValueError("telemetry cannot shrink")
    pad = jnp.zeros(extra, jnp.float32)
    return {
        "counts": jnp.concatenate([tel["counts"], pad]),
        "ema": jnp.concatenate([tel["ema"], pad]),
        "steps": tel["steps"],
        "rows_per_bin": tel["rows_per_bin"],
    }


def utilisation_summary(tel: Telemetry, *, hot_frac: float = 0.1,
                        cold_quantile: float = 0.5) -> dict[str, Any]:
    """Hot/cold/dead utilisation as plain numbers.

    * dead — bins never counted (`counts == 0`): capacity earning nothing.
    * hot mass — share of recent traffic (`ema`) landing on the hottest
      `hot_frac` of bins: concentration (1.0 = one bin takes everything).
    * cold — live bins whose `ema` sits below `cold_quantile` of the
      live-bin median: allocated, warm once, barely read now.

    The structured form the controller and the obs gauges consume;
    `utilisation_report` renders the same numbers as benchmark rows.
    """
    counts = np.asarray(tel["counts"], np.float64)
    ema = np.asarray(tel["ema"], np.float64)
    bins = counts.size
    dead = counts == 0
    dead_frac = float(dead.mean()) if bins else 0.0
    total = float(ema.sum())
    k = max(1, int(round(bins * hot_frac)))
    hot_mass = (float(np.sort(ema)[-k:].sum()) / total) if total > 0 else 0.0
    live = ema[~dead]
    if live.size:
        thresh = cold_quantile * float(np.median(live))
        cold_frac = float((live < thresh).mean())
    else:
        cold_frac = 0.0
    return {
        "bins": bins,
        "rows_per_bin": int(tel["rows_per_bin"]),
        "steps": int(tel["steps"]),
        "dead_frac": round(dead_frac, 4),
        "hot_frac": hot_frac,
        "hot_mass": round(hot_mass, 4),
        "cold_frac": round(cold_frac, 4),
    }


def utilisation_report(tel: Telemetry, *, prefix: str = "util",
                       hot_frac: float = 0.1,
                       cold_quantile: float = 0.5) -> list[list[Any]]:
    """`utilisation_summary` rendered as benchmark rows.

    Rows carry `us_per_call = 0.0` — they are derived/analytic rows, which
    the bench gate (`tools/check_bench.py`) tracks for presence only.
    """
    s = utilisation_summary(tel, hot_frac=hot_frac,
                            cold_quantile=cold_quantile)
    meta = (f"bins={s['bins']} rows_per_bin={s['rows_per_bin']} "
            f"steps={s['steps']}")
    return [
        [f"{prefix}_dead_frac", 0.0, f"{s['dead_frac']:.4f} {meta}"],
        [f"{prefix}_hot{int(round(hot_frac * 100))}_mass", 0.0,
         f"{s['hot_mass']:.4f} {meta}"],
        [f"{prefix}_cold_frac", 0.0, f"{s['cold_frac']:.4f} {meta}"],
    ]
