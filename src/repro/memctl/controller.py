"""The lifecycle policy loop: when to grow, when to spill.

`MemoryController` owns the *decisions*; `growth`/`migrate` own the
mechanics.  Two call sites drive it:

* **Trainer** (`launch/train.py --grow-at STEP:LOG2[,STEP:LOG2...]`):
  `on_train_step` fires each scheduled growth exactly once when its step
  arrives, growing params + Adam moments and returning the new
  `ModelConfig` — the trainer re-jits its step function and continues.
  `catch_up` applies growths that already happened before a resumed
  checkpoint's step, so the restore target has the grown shape.
* **Serve engine** (`ServeEngine(..., controller=...)`): `on_tick` runs
  between decode ticks.  When the dense memory table's device bytes
  exceed `hbm_budget_bytes` (or at the deterministic `spill_at_tick`, for
  tests and demos), it migrates the table to the tiered placement —
  `ServeEngine.swap_model` rebuilds the jitted steps around the new
  params while the slot pool and KV cache carry every in-flight request
  across the move.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

from repro import obs
from repro.core import lookup
from repro.memctl import growth, migrate, telemetry


def parse_grow_at(arg: str) -> tuple[tuple[int, int], ...]:
    """Parse `--grow-at` syntax: "STEP:NEW_LOG2[,STEP:NEW_LOG2...]"."""
    events = []
    for part in arg.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            step_s, log2_s = part.split(":")
            events.append((int(step_s), int(log2_s)))
        except ValueError:
            raise ValueError(
                f"bad --grow-at entry {part!r}; expected STEP:NEW_LOG2"
            ) from None
    events.sort()
    for (s0, l0), (s1, l1) in zip(events, events[1:]):
        if s1 == s0:
            raise ValueError(
                f"--grow-at steps must be distinct: step {s0} appears "
                f"twice (grow straight to 2^{max(l0, l1)} instead)"
            )
        if l1 <= l0:
            raise ValueError(
                f"--grow-at sizes must increase: step {s1} grows to "
                f"2^{l1} after step {s0} grew to 2^{l0}"
            )
    return tuple(events)


@dataclasses.dataclass(frozen=True)
class LifecyclePolicy:
    """What the controller reacts to (all triggers optional)."""

    grow_at: tuple[tuple[int, int], ...] = ()  # (step, new_log2_locations)
    hbm_budget_bytes: int | None = None        # serve: spill dense beyond
    spill_at_tick: int | None = None           # serve: deterministic spill
    spill_tiered: Any = None                   # TieredSpec for the spill
    # per-tenant memory overlays (repro.serving.overlay): enforced on the
    # same tick against the engine's OverlayManager.  Detached tenants
    # idle for `tenant_ttl_ticks` expire; when total overlay bytes exceed
    # `tenant_budget_bytes`, least-recently-used detached tenants are
    # offloaded.  With `overlay_spill_dir` both paths spill to host .npz
    # (restored transparently on next attach) instead of dropping.
    tenant_ttl_ticks: int | None = None
    tenant_budget_bytes: int | None = None
    overlay_spill_dir: str | None = None


def _default_spill_spec(num_locations: int):
    from repro.memstore import TieredSpec

    # shard_rows must divide N (a power of two); ~32 shards, >=512 rows
    shard_rows = max(512, min(8192, num_locations // 32))
    while num_locations % shard_rows:
        shard_rows //= 2
    return TieredSpec(shard_rows=shard_rows,
                      cache_slots=max(2, (num_locations // shard_rows) // 4))


class MemoryController:
    """Policy loop over `repro.memctl.growth` / `.migrate` (see module
    docstring for the two call sites)."""

    def __init__(self, policy: LifecyclePolicy):
        self.policy = policy
        # grow_at events already applied — keyed by the full (step, log2)
        # pair, and shared by on_train_step and catch_up, so a run and its
        # resumed relaunch apply exactly the same schedule
        self._grown: set[tuple[int, int]] = set()
        self._spilled = False
        self.events: list[dict[str, Any]] = []  # applied, for logs/reports

    # ------------------------------------------------------------- training

    def _apply_growth(self, params, model_cfg, opt_state, step: int,
                      new_log2: int):
        new_n = 2 ** new_log2
        obs.gauge("memctl.num_locations").set(
            model_cfg.lram.num_locations
        )
        t0 = time.perf_counter()
        with obs.span("memctl.grow", step=step, new_log2=new_log2):
            params, model_cfg, opt_state = growth.grow_model(
                params, model_cfg, new_n, opt_state=opt_state
            )
        pause_s = round(time.perf_counter() - t0, 4)
        self._grown.add((step, new_log2))
        self.events.append({
            "event": "grow", "step": step, "new_log2": new_log2,
            "pause_s": pause_s,
        })
        obs.gauge("memctl.num_locations").set(new_n)
        obs.emit_event("memctl.grow", step=step, new_log2=new_log2,
                       pause_s=pause_s)
        return params, model_cfg, opt_state

    def on_train_step(self, step: int, params, model_cfg, opt_state=None):
        """Fire scheduled growths whose step has arrived.  Returns
        `(params, model_cfg, opt_state, changed)`; on `changed`, re-jit
        the train step against the new config."""
        changed = False
        for ev_step, new_log2 in self.policy.grow_at:
            if ev_step == step and (ev_step, new_log2) not in self._grown \
                    and 2 ** new_log2 > model_cfg.lram.num_locations:
                params, model_cfg, opt_state = self._apply_growth(
                    params, model_cfg, opt_state, ev_step, new_log2
                )
                changed = True
        return params, model_cfg, opt_state, changed

    def catch_up(self, resume_step: int, params, model_cfg, opt_state=None):
        """Apply every growth that fired before `resume_step` (exclusive of
        events at `resume_step` itself, which the loop will fire), so a
        checkpoint taken after growth restores into the grown shape."""
        changed = False
        for ev_step, new_log2 in self.policy.grow_at:
            if ev_step < resume_step \
                    and (ev_step, new_log2) not in self._grown \
                    and 2 ** new_log2 > model_cfg.lram.num_locations:
                params, model_cfg, opt_state = self._apply_growth(
                    params, model_cfg, opt_state, ev_step, new_log2
                )
                changed = True
        return params, model_cfg, opt_state, changed

    # -------------------------------------------------------------- serving

    def _table_device_bytes(self, model_cfg) -> int:
        lram = model_cfg.lram
        return (len(model_cfg.lram_layers)
                * lram.num_locations * lram.table_bytes_per_entry)

    def _spill_due(self, engine) -> bool:
        pol = self.policy
        if pol.spill_at_tick is not None \
                and engine.ticks >= pol.spill_at_tick:
            return True
        return (pol.hbm_budget_bytes is not None
                and self._table_device_bytes(engine.cfg)
                > pol.hbm_budget_bytes)

    def _overlay_tick(self, engine) -> None:
        """Enforce per-tenant overlay TTL / byte budget against the
        engine's OverlayManager (attached tenants are never touched, so
        in-flight requests ride through)."""
        pol = self.policy
        if pol.tenant_ttl_ticks is None and pol.tenant_budget_bytes is None:
            return
        manager = getattr(engine, "overlays", None)
        if manager is None:
            return
        new_events = manager.enforce(
            tick=engine.ticks,
            ttl_ticks=pol.tenant_ttl_ticks,
            budget_bytes=pol.tenant_budget_bytes,
            spill_dir=pol.overlay_spill_dir,
        )
        self.events.extend(new_events)
        for ev in new_events:
            obs.emit_event("memctl.overlay", **{
                k: (v if isinstance(v, (int, float, str, bool)) else str(v))
                for k, v in ev.items()
            })

    def _utilisation_gauges(self, engine) -> None:
        """Refresh memctl.util_* gauges from the stores' own per-shard
        counters (plans with `row_stats=True`).  Only runs with the
        registry armed: the summary sorts per-shard counts host-side."""
        if not obs.enabled():
            return
        for _, store in getattr(engine, "stores", []):
            if not hasattr(store, "row_stats"):
                continue
            s = telemetry.utilisation_summary(telemetry.store_telemetry(store))
            obs.gauge("memctl.util_dead_frac").set(s["dead_frac"])
            obs.gauge("memctl.util_hot_mass").set(s["hot_mass"])
            obs.gauge("memctl.util_cold_frac").set(s["cold_frac"])
            break  # one memory table per model today

    def on_tick(self, engine) -> bool:
        """Between-decode-ticks hook: spill a dense memory table that has
        outgrown its HBM budget to the tiered store, and enforce the
        per-tenant overlay lifecycle.  Returns True when the engine's
        model was swapped (the caller refreshes its cached store-stat
        baseline)."""
        self._overlay_tick(engine)
        self._utilisation_gauges(engine)
        if self._spilled or engine.cfg.lram is None:
            return False
        if not (self.policy.hbm_budget_bytes is not None
                or self.policy.spill_at_tick is not None):
            return False
        plans = lookup.model_plans(engine.cfg)
        if not plans or plans[0].placement != "dense":
            self._spilled = True  # already offloaded: nothing to spill
            return False
        if not self._spill_due(engine):
            return False
        lram = engine.cfg.lram
        # precedence: explicit policy spec > the config's own tuned
        # TieredSpec (a dense-overridden tiered arch keeps its geometry)
        # > generic defaults sized from N
        spec = (self.policy.spill_tiered or lram.tiered
                or _default_spill_spec(lram.num_locations))
        dst = dataclasses.replace(lram, interp_impl="tiered", tiered=spec)
        obs.gauge("memctl.table_device_bytes").set(
            self._table_device_bytes(engine.cfg)
        )
        t0 = time.perf_counter()
        with obs.span("memctl.spill", tick=engine.ticks):
            params, model_cfg = migrate.migrate_model(
                engine.params, engine.cfg, dst
            )
            engine.swap_model(params, model_cfg)
            for _, store in engine.stores:
                store.warm()
        pause_s = round(time.perf_counter() - t0, 4)
        # post-spill device footprint is the tiered caches, not the table
        obs.gauge("memctl.table_device_bytes").set(sum(
            store.cache_np.nbytes
            for _, store in engine.stores if hasattr(store, "cache_np")
        ))
        self._spilled = True
        self.events.append({
            "event": "spill", "tick": engine.ticks,
            "placement": "dense->tiered",
            "pause_s": pause_s,
        })
        obs.emit_event("memctl.spill", tick=engine.ticks,
                       placement="dense->tiered", pause_s=pause_s)
        return True
