"""Stateless synthetic data (batch derived from (config, step) — no
loader state to checkpoint).

Public surface: `DataConfig`, `get_batch` (mlm/clm objectives), and
`make_fact_table` / `repro.data.synthetic.fact_eval_batch` for the
fact-recall probe the memory layer is evaluated on.
"""

from repro.data.synthetic import (  # noqa: F401
    DataConfig,
    get_batch,
    make_fact_table,
)
