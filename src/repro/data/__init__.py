from repro.data.synthetic import (  # noqa: F401
    DataConfig,
    get_batch,
    make_fact_table,
)
