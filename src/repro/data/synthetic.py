"""Stateless, host-sharded synthetic data pipeline.

The paper trains on 60 GB of web text, which is not available offline
(DESIGN.md §7).  This pipeline generates a deterministic synthetic corpus
whose statistics exercise the same mechanism the paper tests:

  * `zipf`  — Zipf-distributed token stream (natural-language-like marginals),
  * `facts` — the memory-recall task: a fixed table of (key-trigram ->
    value-trigram) "facts" is planted into the stream.  Recalling a fact
    requires associative memory: this is where LRAM/PKM capacity shows up in
    the loss, reproducing the *shape* of the paper's Table 2 at CPU scale.
  * MLM masking (BERT recipe: 15% positions; 80/10/10 mask/random/keep) or
    CLM next-token labels.

Stateless: batch `i` for host shard `(s, n)` is a pure function of
(seed, i, s) — resuming == restoring a step counter, and elastic rescaling
re-partitions the stream with no data-state in the checkpoint.
"""

from __future__ import annotations

import dataclasses

import numpy as np

IGNORE = -100
_FACT_LEN = 3


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    kind: str = "facts"         # zipf | facts
    objective: str = "mlm"      # mlm | clm
    num_facts: int = 4096
    fact_density: float = 0.5   # fraction of sequences carrying facts
    mask_prob: float = 0.15
    zipf_a: float = 1.2
    seed: int = 1234

    @property
    def mask_token(self) -> int:
        return self.vocab_size - 1


def make_fact_table(cfg: DataConfig) -> np.ndarray:
    """(num_facts, 2, 3): key trigram -> value trigram, fixed by seed."""
    rng = np.random.default_rng(cfg.seed)
    return rng.integers(
        0, cfg.vocab_size - 1, size=(cfg.num_facts, 2, _FACT_LEN)
    ).astype(np.int32)


def _zipf_tokens(rng, cfg: DataConfig, shape):
    # bounded zipf via inverse-cdf over the vocab
    ranks = np.arange(1, cfg.vocab_size)
    weights = 1.0 / ranks**cfg.zipf_a
    cdf = np.cumsum(weights) / weights.sum()
    u = rng.random(shape)
    return np.searchsorted(cdf, u).astype(np.int32)


def _plant_facts(rng, tokens, cfg: DataConfig, table):
    b, s = tokens.shape
    carry = rng.random(b) < cfg.fact_density
    fact_ids = rng.integers(0, cfg.num_facts, size=b)
    starts = rng.integers(0, s - 2 * _FACT_LEN, size=b)
    for i in range(b):
        if carry[i]:
            k, v = table[fact_ids[i]]
            st = starts[i]
            tokens[i, st : st + _FACT_LEN] = k
            tokens[i, st + _FACT_LEN : st + 2 * _FACT_LEN] = v
    return tokens


def _mlm_mask(rng, tokens, cfg: DataConfig):
    b, s = tokens.shape
    labels = np.full_like(tokens, IGNORE)
    mask = rng.random((b, s)) < cfg.mask_prob
    labels[mask] = tokens[mask]
    action = rng.random((b, s))
    tokens = tokens.copy()
    tokens[mask & (action < 0.8)] = cfg.mask_token
    rand_sel = mask & (action >= 0.8) & (action < 0.9)
    tokens[rand_sel] = rng.integers(
        0, cfg.vocab_size - 1, size=int(rand_sel.sum())
    )
    return tokens, labels


def get_batch(cfg: DataConfig, step: int, *, shard: tuple[int, int] = (0, 1),
              table: np.ndarray | None = None):
    """Batch shard `shard=(index, count)` for global step `step`.

    Returns numpy {"tokens": (b_local, S), "labels": (b_local, S)}."""
    sh_i, sh_n = shard
    assert cfg.global_batch % sh_n == 0
    b_local = cfg.global_batch // sh_n
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, sh_i])
    )
    tokens = _zipf_tokens(rng, cfg, (b_local, cfg.seq_len))
    if cfg.kind == "facts":
        table = table if table is not None else make_fact_table(cfg)
        tokens = _plant_facts(rng, tokens, cfg, table)
    if cfg.objective == "mlm":
        tokens, labels = _mlm_mask(rng, tokens, cfg)
    else:
        labels = np.concatenate(
            [tokens[:, 1:], np.full((b_local, 1), IGNORE, tokens.dtype)],
            axis=1,
        )
    return {"tokens": tokens, "labels": labels}


def fact_eval_batch(cfg: DataConfig, n: int = 256,
                    table: np.ndarray | None = None):
    """Probe batch: every sequence carries a fact and ONLY the value trigram
    is masked — measures pure associative recall (memory-utilisation story).
    """
    table = table if table is not None else make_fact_table(cfg)
    rng = np.random.default_rng(cfg.seed + 999)
    tokens = _zipf_tokens(rng, cfg, (n, cfg.seq_len))
    labels = np.full_like(tokens, IGNORE)
    fact_ids = rng.integers(0, cfg.num_facts, size=n)
    starts = rng.integers(0, cfg.seq_len - 2 * _FACT_LEN, size=n)
    for i in range(n):
        k, v = table[fact_ids[i]]
        st = starts[i]
        tokens[i, st : st + _FACT_LEN] = k
        labels[i, st + _FACT_LEN : st + 2 * _FACT_LEN] = v
        tokens[i, st + _FACT_LEN : st + 2 * _FACT_LEN] = cfg.mask_token
    return {"tokens": tokens, "labels": labels}
