"""Optimized-HLO collective parser.

cost_analysis() has no collective accounting, so the roofline's third term
is derived by scanning the post-SPMD-partitioning HLO text for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops, decoding their (per-device) result shapes and replica groups, and
applying ring wire-cost factors:

    all-reduce       2 (g-1)/g * bytes      (reduce-scatter + all-gather)
    all-gather         (g-1)/g * bytes_out
    reduce-scatter     (g-1)/g * bytes_in   (= bytes_out * g)
    all-to-all         (g-1)/g * bytes
    collective-permute           bytes

Shapes in partitioned HLO are already per-device, so the returned numbers
are wire bytes per device per step.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute")

# one result shape: bf16[4,2048]{1,0} — possibly inside a tuple
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_GROUPS_RE = re.compile(
    r"replica_groups=(\{\{[^}]*\}|\[\d+(?:,\d+)*\]<=\[[\d,]+\])"
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return 2  # conservative default
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(1, first.count(",") + 1)
    # iota format: [G,N]<=[...] -> group size N (last dim)
    dims = g[1:].split("]")[0].split(",")
    return max(1, int(dims[-1]))


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    raw_bytes: dict        # sum of result bytes per op kind
    wire_bytes: dict       # ring-model wire bytes per device per op kind

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict = defaultdict(int)
    raw: dict = defaultdict(float)
    wire: dict = defaultdict(float)
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        shape_str, op, _start = m.group(1), m.group(2), m.group(3)
        # async pairs appear as op-start/op-done: count -start only, and
        # skip the "-done" lines (they don't match: '(-done' not in regex)
        b = _shape_bytes(shape_str)
        if _start and shape_str.startswith("("):
            b //= 2  # async-start result tuples alias (operand, result)
        g = _group_size(line)
        counts[op] += 1
        raw[op] += b
        if op == "all-reduce":
            wire[op] += 2.0 * (g - 1) / g * b
        elif op == "all-gather":
            wire[op] += (g - 1) / g * b
        elif op == "reduce-scatter":
            wire[op] += (g - 1) * b  # input = out*g; (g-1)/g * out*g
        elif op == "all-to-all":
            wire[op] += (g - 1) / g * b
        else:  # collective-permute
            wire[op] += float(b)
    return CollectiveStats(dict(counts), dict(raw), dict(wire))
