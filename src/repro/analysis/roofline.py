"""Roofline analysis over the dry-run artifacts (§Roofline).

    PYTHONPATH=src python -m repro.analysis.roofline [--dir artifacts/dryrun]

Per (arch x shape) single-pod cell, derives the three roofline terms from
the compiled artifact (depth-extrapolated exact counts — see dryrun.py):

    compute    = HLO_FLOPs_per_device / 197 TFLOP/s (bf16)
    memory     = HLO_bytes_per_device / 819 GB/s HBM
    collective = wire_bytes_per_device / 50 GB/s ICI  (ring-model accounting,
                 see analysis/hlo.py; single-pod => all traffic is ICI)

plus MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs (remat + replication waste), the
dominant term, and the roofline fraction
    model_compute_time / max(term)  ("how close to the compute roofline a
perfectly-overlapped execution of this artifact could get").
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12   # bf16 per chip (TPU v5e-class)
HBM_BW = 819e9        # bytes/s per chip
ICI_BW = 50e9         # bytes/s per link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}


def analyze_artifact(art: dict) -> dict | None:
    if art.get("status") != "ok":
        return None
    ex = art.get("extrapolated") or art.get("scanned")
    src = "extrapolated" if "extrapolated" in art else "scanned"
    flops = ex.get("flops_per_device")
    bytes_ = ex.get("bytes_per_device")
    wire = ex.get("total_wire_bytes_per_device") or 0.0
    if flops is None:
        return None
    devices = art["devices"]
    shape = art["shape"]
    tokens = SHAPE_TOKENS[shape]
    mult = 6 if shape.startswith("train") else 2
    model_flops_global = mult * art["params_active"] * tokens
    model_flops_dev = model_flops_global / devices

    t_compute = flops / PEAK_FLOPS
    t_memory = (bytes_ or 0.0) / HBM_BW
    t_coll = wire / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values())
    return {
        "arch": art["arch"],
        "shape": shape,
        "source": src,
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "wire_bytes_per_device": wire,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_global": model_flops_global,
        "useful_flops_ratio": model_flops_dev / flops if flops else None,
        "roofline_fraction": (
            (model_flops_dev / PEAK_FLOPS) / t_bound if t_bound else None
        ),
        "step_time_bound_s": t_bound,
    }


def _fmt_t(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{1e3 * x:.1f}ms"


def render_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "useful-FLOP ratio | roofline frac |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["shape"], r["arch"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_t(r['t_compute_s'])} "
            f"| {_fmt_t(r['t_memory_s'])} | {_fmt_t(r['t_collective_s'])} "
            f"| **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="artifacts/dryrun")
    p.add_argument("--mesh", default="single")
    p.add_argument("--out", default="artifacts/roofline.md")
    p.add_argument("--json-out", default="artifacts/roofline.json")
    args = p.parse_args(argv)

    rows, skipped, errors = [], [], []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        with open(path) as f:
            art = json.load(f)
        if art.get("mesh") != args.mesh:
            continue
        if art.get("status") == "skipped":
            skipped.append((art["arch"], art["shape"], art["reason"]))
            continue
        if art.get("status") == "error":
            errors.append((art["arch"], art["shape"],
                           art.get("error", "?")))
            continue
        row = analyze_artifact(art)
        if row:
            rows.append(row)

    table = render_table(rows)
    report = ["# Roofline (single-pod 16x16, per-device terms)", "",
              f"constants: {PEAK_FLOPS/1e12:.0f} TFLOP/s bf16, "
              f"{HBM_BW/1e9:.0f} GB/s HBM, {ICI_BW/1e9:.0f} GB/s ICI", "",
              table, ""]
    if skipped:
        report.append("## Skipped cells")
        for a, s, r in skipped:
            report.append(f"* {a} x {s}: {r}")
    if errors:
        report.append("## Errored cells")
        for a, s, e in errors:
            report.append(f"* {a} x {s}: {e}")
    text = "\n".join(report)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
