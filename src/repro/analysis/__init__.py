"""Static performance analysis (no accelerator required).

Public surface:

  * `repro.analysis.hlo`      — parse compiled HLO for collectives
    (`parse_collectives`: op counts + wire bytes per mesh axis)
  * `repro.analysis.roofline` — arithmetic-intensity / bandwidth roofline
    estimates for the lookup and dense paths
"""
