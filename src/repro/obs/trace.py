"""Span tracer: parent-linked wall-time spans with attached metric deltas.

    with obs.span("serve.decode_tick", tick=7):
        ...

Spans form a per-thread stack: a span opened inside another records the
outer span's id as its parent, so an exported trace reconstructs the call
tree (tick -> admit -> prefill, tick -> decode, ...).  On exit each span
carries:

* wall time (`perf_counter` delta),
* user attributes (the keyword args),
* **metric deltas** — the change in every registry *counter* over the
  span's lifetime, nonzero entries only.  A `serve.decode_tick` span thus
  shows exactly how many store fills / hits / bytes that one tick cost,
  without the instrumented layers knowing about each other.

A disabled tracer's `span()` is a shared no-op context manager (one dict
lookup, no allocation) — the same off-is-free rule as the registry.

`profile_dir` arms `jax.profiler` capture: spans entered with
`profile=True` run under `jax.profiler.trace(profile_dir)` (outermost
profiled span only — the profiler is process-global), so
`--profile-dir /tmp/prof` turns a marked span into a full XLA trace you
can open in TensorBoard/Perfetto without touching the call site.
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import threading
import time
from typing import Any, Callable

from repro.obs.registry import MetricsRegistry


@dataclasses.dataclass
class Span:
    """One finished (or in-flight) span."""

    name: str
    span_id: int
    parent_id: int | None
    t0_s: float                      # process-relative (perf_counter)
    attrs: dict[str, Any]
    dur_s: float | None = None
    metrics: dict[str, float] = dataclasses.field(default_factory=dict)

    def set_attr(self, key: str, value: Any) -> None:
        self.attrs[key] = value

    def to_event(self) -> dict[str, Any]:
        """The JSONL `span` event (see repro.obs.export.validate_event)."""
        return {
            "kind": "span",
            "name": self.name,
            "id": self.span_id,
            "parent": self.parent_id,
            "t0_s": round(self.t0_s, 6),
            "dur_s": round(self.dur_s or 0.0, 6),
            "attrs": self.attrs,
            "metrics": {k: round(v, 6) for k, v in self.metrics.items()},
        }


class _NullSpan:
    """What a disabled tracer yields: attribute writes vanish."""

    __slots__ = ()

    def set_attr(self, key: str, value: Any) -> None:
        pass


_NULL_SPAN = _NullSpan()


@contextlib.contextmanager
def _null_ctx():
    yield _NULL_SPAN


class Tracer:
    """Per-process tracer over a `MetricsRegistry` (for counter deltas).

    `on_finish` (set by `obs.configure`) streams each finished span to the
    JSONL exporter; finished spans are also kept in a bounded in-memory
    list (`finished`) for reports and tests.
    """

    def __init__(self, registry: MetricsRegistry | None = None, *,
                 enabled: bool = True, max_spans: int = 100_000,
                 profile_dir: str | None = None,
                 on_finish: Callable[[Span], None] | None = None):
        self.enabled = enabled
        self.registry = registry
        self.max_spans = max_spans
        self.profile_dir = profile_dir
        self.on_finish = on_finish
        self.finished: list[Span] = []
        self.dropped = 0
        self._ids = itertools.count(1)
        self._local = threading.local()
        self._lock = threading.Lock()
        self._profiling = False  # a profiled span is already active

    def _stack(self) -> list[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @contextlib.contextmanager
    def span(self, name: str, *, profile: bool = False, **attrs):
        """Open a span; yields the `Span` (set late attrs on it)."""
        if not self.enabled:
            yield _NULL_SPAN
            return
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        sp = Span(
            name=name, span_id=next(self._ids), parent_id=parent,
            t0_s=time.perf_counter(), attrs=dict(attrs),
        )
        before = (self.registry.counter_values()
                  if self.registry is not None else {})
        stack.append(sp)
        profiler_ctx = contextlib.nullcontext()
        started_profile = False
        if profile and self.profile_dir and not self._profiling:
            try:
                import jax

                profiler_ctx = jax.profiler.trace(self.profile_dir)
                self._profiling = started_profile = True
            except Exception:  # profiler unavailable: span still records
                profiler_ctx = contextlib.nullcontext()
        try:
            with profiler_ctx:
                yield sp
        finally:
            if started_profile:
                self._profiling = False
            stack.pop()
            sp.dur_s = time.perf_counter() - sp.t0_s
            if self.registry is not None:
                after = self.registry.counter_values()
                sp.metrics = {
                    k: after[k] - before.get(k, 0.0)
                    for k in after
                    if after[k] - before.get(k, 0.0) != 0.0
                }
            with self._lock:
                if len(self.finished) < self.max_spans:
                    self.finished.append(sp)
                else:
                    self.dropped += 1
            if self.on_finish is not None:
                self.on_finish(sp)

    def span_count(self) -> int:
        with self._lock:
            return len(self.finished) + self.dropped


class _NullTracer:
    """Disabled tracer: `span()` returns a shared no-op context."""

    enabled = False
    finished: list[Span] = []
    dropped = 0

    def span(self, name: str, **attrs):
        return _null_ctx()

    def span_count(self) -> int:
        return 0


NULL_TRACER = _NullTracer()
