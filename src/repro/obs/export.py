"""Exporters: append-only JSONL event log + Prometheus textfile snapshot.

Two sinks, one schema (`validate_event`):

* **JSONL** (`JsonlExporter`) — one JSON object per line, streamed as
  events happen (spans on finish, lifecycle events as they fire, metric
  snapshots at flush), so a crashed run still leaves a readable log.
  Event kinds:

    {"kind": "span",    "name", "id", "parent", "t0_s", "dur_s",
                        "attrs": {...}, "metrics": {...}}
    {"kind": "event",   "name", "t_s", "attrs": {...}}
    {"kind": "metrics", "t_s", "metrics": {name: snapshot, ...}}

* **Prometheus textfile** (`write_prometheus`) — the node-exporter
  textfile-collector format: the whole registry as `# TYPE`-annotated
  families, dots rewritten to underscores, histograms in cumulative
  `_bucket{le=...}` form.  Written at flush/exit (a snapshot, not a
  stream): point a textfile collector at `--metrics-dir` and the run's
  final state scrapes like any other exporter.

`metrics_doc` / `validate_metrics_doc` define the summary-document
`metrics` field (`EngineReport.summary`, `benchmarks.run --json`) that
`tools/check_bench.py` gates on: schema id, enabled flag, and the full
registry snapshot.
"""

from __future__ import annotations

import json
import math
import re
import threading
import time
from typing import Any

from repro.obs.registry import MetricsRegistry

METRICS_SCHEMA = "repro.obs.v1"
EVENT_KINDS = ("span", "event", "metrics")

_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_.]*$")


def _check_num(doc: dict, key: str, ctx: str) -> None:
    v = doc.get(key)
    if isinstance(v, bool) or not isinstance(v, (int, float)) \
            or not math.isfinite(v):
        raise ValueError(f"{ctx}: {key!r} must be a finite number, got {v!r}")


def validate_event(doc: Any) -> None:
    """Assert `doc` is a well-formed JSONL event; raises ValueError."""
    if not isinstance(doc, dict):
        raise ValueError(f"event must be an object, got {type(doc)}")
    kind = doc.get("kind")
    if kind not in EVENT_KINDS:
        raise ValueError(f"event kind must be one of {EVENT_KINDS}, "
                         f"got {kind!r}")
    if kind in ("span", "event"):
        name = doc.get("name")
        if not isinstance(name, str) or not _NAME_RE.match(name):
            raise ValueError(f"{kind} event: bad name {name!r}")
        attrs = doc.get("attrs", {})
        if not isinstance(attrs, dict):
            raise ValueError(f"{kind} event {name}: attrs must be an object")
    if kind == "span":
        _check_num(doc, "t0_s", f"span {doc.get('name')}")
        _check_num(doc, "dur_s", f"span {doc.get('name')}")
        if doc.get("dur_s") < 0:
            raise ValueError(f"span {doc.get('name')}: negative dur_s")
        if not isinstance(doc.get("id"), int):
            raise ValueError(f"span {doc.get('name')}: id must be an int")
        parent = doc.get("parent")
        if parent is not None and not isinstance(parent, int):
            raise ValueError(
                f"span {doc.get('name')}: parent must be an int or null"
            )
        metrics = doc.get("metrics", {})
        if not isinstance(metrics, dict) or not all(
            isinstance(k, str) and isinstance(v, (int, float))
            and not isinstance(v, bool) and math.isfinite(v)
            for k, v in metrics.items()
        ):
            raise ValueError(f"span {doc.get('name')}: bad metrics map")
    if kind == "event":
        _check_num(doc, "t_s", f"event {doc.get('name')}")
    if kind == "metrics":
        _check_num(doc, "t_s", "metrics event")
        _validate_snapshot(doc.get("metrics"))


def _validate_snapshot(metrics: Any) -> None:
    if not isinstance(metrics, dict):
        raise ValueError("metrics snapshot must be an object")
    for name, m in metrics.items():
        if not isinstance(name, str) or not _NAME_RE.match(name):
            raise ValueError(f"metrics snapshot: bad metric name {name!r}")
        if not isinstance(m, dict):
            raise ValueError(f"metric {name}: snapshot must be an object")
        kind = m.get("kind")
        if kind in ("counter", "gauge"):
            _check_num(m, "value", f"metric {name}")
        elif kind == "histogram":
            buckets, counts = m.get("buckets"), m.get("counts")
            if not (isinstance(buckets, list) and isinstance(counts, list)
                    and len(counts) == len(buckets) + 1
                    and all(isinstance(c, int) and c >= 0 for c in counts)):
                raise ValueError(f"histogram {name}: bad buckets/counts")
            _check_num(m, "sum", f"histogram {name}")
        else:
            raise ValueError(f"metric {name}: unknown kind {kind!r}")


def validate_metrics_doc(doc: Any) -> None:
    """Assert `doc` is a summary-document `metrics` field (the shape
    `tools/check_bench.py` gates on).  Raises ValueError."""
    if not isinstance(doc, dict):
        raise ValueError(f"metrics doc must be an object, got {type(doc)}")
    if doc.get("schema") != METRICS_SCHEMA:
        raise ValueError(
            f"metrics doc schema must be {METRICS_SCHEMA!r}, "
            f"got {doc.get('schema')!r}"
        )
    if not isinstance(doc.get("enabled"), bool):
        raise ValueError("metrics doc: 'enabled' must be a bool")
    if not isinstance(doc.get("spans"), int) or doc["spans"] < 0:
        raise ValueError("metrics doc: 'spans' must be a non-negative int")
    _validate_snapshot(doc.get("metrics"))


def metrics_doc(registry: MetricsRegistry, *, spans: int = 0) -> dict:
    """The summary-document `metrics` field for this registry's state."""
    return {
        "schema": METRICS_SCHEMA,
        "enabled": registry.enabled,
        "spans": spans,
        "metrics": registry.snapshot(),
    }


class JsonlExporter:
    """Append-only JSONL event sink (validated, flushed per event)."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._f = open(path, "a", encoding="utf-8")

    def write(self, doc: dict) -> None:
        validate_event(doc)
        line = json.dumps(doc, sort_keys=True)
        with self._lock:
            if self._f.closed:
                return
            self._f.write(line + "\n")
            self._f.flush()

    def write_span(self, span) -> None:
        self.write(span.to_event())

    def write_event(self, name: str, **attrs) -> None:
        self.write({
            "kind": "event", "name": name,
            "t_s": round(time.perf_counter(), 6), "attrs": attrs,
        })

    def write_snapshot(self, registry: MetricsRegistry) -> None:
        self.write({
            "kind": "metrics", "t_s": round(time.perf_counter(), 6),
            "metrics": registry.snapshot(),
        })

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()


def read_jsonl(path: str) -> list[dict]:
    """Load and re-validate a JSONL event log (tests, analysis)."""
    events = []
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            doc = json.loads(line)
            try:
                validate_event(doc)
            except ValueError as e:
                raise ValueError(f"{path}:{i + 1}: {e}") from None
            events.append(doc)
    return events


# ---------------------------------------------------------------------------
# Prometheus textfile snapshot
# ---------------------------------------------------------------------------

def _prom_name(name: str) -> str:
    return "repro_" + re.sub(r"[^a-zA-Z0-9_]", "_", name)


def _prom_num(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    return repr(float(v))


def prometheus_text(registry: MetricsRegistry) -> str:
    """The whole registry in Prometheus text exposition format."""
    out: list[str] = []
    for m in registry.metrics():
        pname = _prom_name(m.name)
        if m.help:
            out.append(f"# HELP {pname} {m.help}")
        out.append(f"# TYPE {pname} {m.kind}")
        snap = m.snapshot()
        if m.kind in ("counter", "gauge"):
            suffix = "_total" if m.kind == "counter" else ""
            out.append(f"{pname}{suffix} {_prom_num(snap['value'])}")
        else:  # histogram: cumulative le buckets + sum + count
            cum = 0
            for bound, c in zip(snap["buckets"] + [math.inf],
                                snap["counts"]):
                cum += c
                out.append(
                    f'{pname}_bucket{{le="{_prom_num(bound)}"}} {cum}'
                )
            out.append(f"{pname}_sum {_prom_num(snap['sum'])}")
            out.append(f"{pname}_count {snap['count']}")
    return "\n".join(out) + ("\n" if out else "")


_PROM_LINE_RE = re.compile(
    r"^(#\s(HELP|TYPE)\s[a-zA-Z_][a-zA-Z0-9_]*(\s.*)?"
    r"|[a-zA-Z_][a-zA-Z0-9_]*(\{le=\"[^\"]+\"\})?\s\S+)$"
)


def validate_prometheus_text(text: str) -> None:
    """Line-level sanity check of the exposition format (tests)."""
    for i, line in enumerate(text.splitlines()):
        if line and not _PROM_LINE_RE.match(line):
            raise ValueError(f"prometheus text line {i + 1} invalid: "
                             f"{line!r}")


def write_prometheus(registry: MetricsRegistry, path: str) -> None:
    text = prometheus_text(registry)
    validate_prometheus_text(text)
    with open(path, "w", encoding="utf-8") as f:
        f.write(text)
