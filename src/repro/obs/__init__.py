"""Unified observability: metrics registry, span tracer, exporters.

The cross-cutting measurement layer every subsystem instruments against
(docs/observability.md):

* `registry` — process-wide `MetricsRegistry` (counters, gauges,
  fixed-bucket histograms; `repro.obs.registry` also holds the jit-safe
  device-side accumulators drained at step/tick boundaries).
* `trace` — `Tracer`/`Span`: parent-linked wall-time spans with attached
  counter deltas, optional `jax.profiler` capture for marked spans.
* `export` — append-only JSONL event log + Prometheus textfile snapshot,
  both schema-validated; `metrics_doc` is the summary-document field
  `tools/check_bench.py` gates on.

**Off by default, and off means free**: until `configure()` runs, every
`counter()`/`gauge()`/`histogram()` call returns a shared null metric and
`span()` a shared null context — pure host-side no-ops, zero jitted
device work, bit-identical numerics (tests/test_obs.py asserts both).
The launch CLIs arm it via `--metrics-dir` (and `--profile-dir` for
profiler capture of marked spans).

Instrumentation pattern (call sites fetch through the module so a late
`configure()` is picked up):

    from repro import obs
    obs.counter("memstore.fills").inc()
    with obs.span("serve.decode_tick", tick=t):
        ...
"""

from __future__ import annotations

import os
import threading

from repro.obs import export as export  # noqa: F401  (public submodule)
from repro.obs.export import (  # noqa: F401
    JsonlExporter,
    metrics_doc as _metrics_doc,
    prometheus_text,
    read_jsonl,
    validate_event,
    validate_metrics_doc,
    write_prometheus,
)
from repro.obs.registry import (  # noqa: F401
    LATENCY_BUCKETS_S,
    MetricsRegistry,
    NULL_METRIC,
    accum_add,
    accum_init,
    hist_bucket_add,
)
from repro.obs.trace import NULL_TRACER, Span, Tracer  # noqa: F401

_lock = threading.Lock()
_registry = MetricsRegistry(enabled=False)
_tracer = NULL_TRACER
_exporter: JsonlExporter | None = None
_metrics_dir: str | None = None

JSONL_NAME = "metrics.jsonl"
PROM_NAME = "metrics.prom"


def registry() -> MetricsRegistry:
    """The process-wide registry (disabled until `configure()`)."""
    return _registry


def tracer():
    return _tracer


def enabled() -> bool:
    return _registry.enabled


def counter(name: str, help: str = ""):
    return _registry.counter(name, help)


def gauge(name: str, help: str = ""):
    return _registry.gauge(name, help)


def histogram(name: str, help: str = "", buckets=LATENCY_BUCKETS_S):
    return _registry.histogram(name, help, buckets)


def span(name: str, **attrs):
    """Open a span on the process tracer (no-op context until configured)."""
    return _tracer.span(name, **attrs)


def emit_event(name: str, **attrs) -> None:
    """Stream a lifecycle event to the JSONL log (dropped when off)."""
    if _exporter is not None:
        _exporter.write_event(name, **attrs)


def configure(*, metrics_dir: str | None = None,
              profile_dir: str | None = None,
              enabled: bool = True) -> MetricsRegistry:
    """Arm (or re-arm) the process observability state.

    `metrics_dir` activates the exporters: spans stream to
    `<dir>/metrics.jsonl` as they finish, and `flush()` (or process
    helpers like the launch CLIs at exit) snapshots the registry there
    plus a `<dir>/metrics.prom` Prometheus textfile.  Without a dir the
    registry/tracer still run in memory (reports, tests).
    `profile_dir` arms `jax.profiler` capture for `span(..., profile=True)`.
    """
    global _registry, _tracer, _exporter, _metrics_dir
    with _lock:
        if _exporter is not None:
            _exporter.close()
        _registry = MetricsRegistry(enabled=enabled)
        _exporter = None
        _metrics_dir = None
        if not enabled:
            _tracer = NULL_TRACER
            return _registry
        on_finish = None
        if metrics_dir is not None:
            os.makedirs(metrics_dir, exist_ok=True)
            _metrics_dir = metrics_dir
            _exporter = JsonlExporter(os.path.join(metrics_dir, JSONL_NAME))
            on_finish = _exporter.write_span
        _tracer = Tracer(_registry, profile_dir=profile_dir,
                         on_finish=on_finish)
        return _registry


def disable() -> None:
    """Back to the zero-overhead default (tests; idempotent)."""
    configure(enabled=False)


def flush() -> None:
    """Write the current registry to the exporters: a `metrics` JSONL
    snapshot event + the Prometheus textfile.  Safe to call repeatedly
    (each flush appends one snapshot and rewrites the textfile)."""
    with _lock:
        if _exporter is not None:
            _exporter.write_snapshot(_registry)
        if _metrics_dir is not None:
            write_prometheus(_registry,
                             os.path.join(_metrics_dir, PROM_NAME))


def metrics_doc() -> dict:
    """The summary-document `metrics` field for the current state."""
    return _metrics_doc(_registry, spans=_tracer.span_count())
