"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

Three rules shape the design:

1. **Disabled means free.**  A disabled registry hands every caller the
   same null-metric singletons whose mutators are empty methods — call
   sites instrument unconditionally (`obs.counter("x").inc()`), and the
   off path costs one dict lookup + one no-op call, with *zero* jitted
   device work (nothing here ever enters a traced function unless the
   caller opts into the device accumulators below).
2. **Host metrics are thread-safe.**  Store fills run on prefetch worker
   threads and io_callback bodies run on the XLA callback pool, so every
   mutator takes the metric's lock.  Snapshots are consistent per metric,
   not across metrics — good enough for monitoring.
3. **Device-side accumulation drains at boundaries.**  Inside jit, use the
   pure helpers (`accum_init`/`accum_add`/`hist_bucket_add` — the
   `repro.memctl.telemetry_update` segment-sum pattern: one `.at[].add`),
   carry the accumulator like optimizer state, and drain it into the host
   registry at step/tick boundaries (`Histogram.merge_counts`,
   `Counter.inc`).  The traced graph never holds a host metric.

Metric names are dotted (`serve.decode_step_s`, `memstore.fill_bytes`);
the Prometheus exporter rewrites dots to underscores.
"""

from __future__ import annotations

import math
import threading
from typing import Any, Sequence

import jax.numpy as jnp

# log-ish spaced seconds: 100us .. 10s — the default latency buckets
LATENCY_BUCKETS_S = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


class Counter:
    """Monotonically increasing value (`.inc`)."""

    __slots__ = ("name", "help", "_value", "_lock")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name}: negative increment {v}")
        with self._lock:
            self._value += v

    def get(self) -> float:
        return self._value

    def snapshot(self) -> dict[str, Any]:
        return {"kind": self.kind, "value": self._value}


class Gauge:
    """Last-write-wins value (`.set` / `.add`)."""

    __slots__ = ("name", "help", "_value", "_lock")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, v: float) -> None:
        with self._lock:
            self._value += float(v)

    def get(self) -> float:
        return self._value

    def snapshot(self) -> dict[str, Any]:
        return {"kind": self.kind, "value": self._value}


class Histogram:
    """Fixed-bucket histogram: counts per bucket, +Inf overflow, sum."""

    __slots__ = ("name", "help", "bounds", "_counts", "_sum", "_lock")
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = LATENCY_BUCKETS_S):
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b1 <= b0 for b0, b1 in zip(bounds, bounds[1:])):
            raise ValueError(
                f"histogram {name}: buckets must be non-empty and "
                f"strictly increasing, got {bounds}"
            )
        self.name = name
        self.help = help
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)  # last = +Inf overflow
        self._sum = 0.0
        self._lock = threading.Lock()

    def _bucket(self, v: float) -> int:
        # first bound >= v (cumulative `le` semantics, like Prometheus)
        for i, b in enumerate(self.bounds):
            if v <= b:
                return i
        return len(self.bounds)

    def observe(self, v: float) -> None:
        i = self._bucket(float(v))
        with self._lock:
            self._counts[i] += 1
            self._sum += float(v)

    def merge_counts(self, counts, total: float = 0.0) -> None:
        """Drain a device-side accumulator (`hist_bucket_add` carry, or any
        per-bucket count vector of length len(bounds)+1) into this host
        histogram.  `total` adds to the running sum (pass the accumulated
        value sum when the caller tracked it)."""
        counts = [int(c) for c in counts]
        if len(counts) != len(self._counts):
            raise ValueError(
                f"histogram {self.name}: expected {len(self._counts)} "
                f"bucket counts, got {len(counts)}"
            )
        with self._lock:
            for i, c in enumerate(counts):
                self._counts[i] += c
            self._sum += float(total)

    @property
    def count(self) -> int:
        return sum(self._counts)

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        """Bucket-upper-bound estimate of the q-quantile (0..1)."""
        total = self.count
        if not total:
            return 0.0
        rank = q * total
        acc = 0
        for i, c in enumerate(self._counts):
            acc += c
            if acc >= rank:
                return (self.bounds[i] if i < len(self.bounds)
                        else math.inf)
        return math.inf

    def snapshot(self) -> dict[str, Any]:
        return {
            "kind": self.kind,
            "buckets": list(self.bounds),
            "counts": list(self._counts),
            "sum": self._sum,
            "count": self.count,
        }


class _NullMetric:
    """Shared do-nothing metric: what a disabled registry hands out."""

    __slots__ = ()
    name = "<disabled>"
    help = ""
    bounds = LATENCY_BUCKETS_S
    count = 0
    sum = 0.0

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def add(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def merge_counts(self, counts, total: float = 0.0) -> None:
        pass

    def get(self) -> float:
        return 0.0

    def quantile(self, q: float) -> float:
        return 0.0


NULL_METRIC = _NullMetric()


class MetricsRegistry:
    """Name -> metric map.  `enabled=False` is the hard off-switch: every
    factory returns `NULL_METRIC` and `snapshot()` is empty."""

    def __init__(self, *, enabled: bool = True):
        self.enabled = enabled
        self._metrics: dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls, **kw):
        if not self.enabled:
            return NULL_METRIC
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}"
                )
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = LATENCY_BUCKETS_S) -> Histogram:
        return self._get_or_create(name, Histogram, help=help,
                                   buckets=buckets)

    def metrics(self) -> list[Any]:
        with self._lock:
            return list(self._metrics.values())

    def counter_values(self) -> dict[str, float]:
        """Current counter totals (the span tracer's delta snapshot)."""
        with self._lock:
            return {n: m.get() for n, m in self._metrics.items()
                    if isinstance(m, Counter)}

    def snapshot(self) -> dict[str, dict[str, Any]]:
        with self._lock:
            items = list(self._metrics.items())
        return {n: m.snapshot() for n, m in items}

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


# ---------------------------------------------------------------------------
# device-side accumulators (pure, jit-safe; drain at host boundaries)
# ---------------------------------------------------------------------------

def accum_init(bins: int):
    """Zeroed device-side scatter-add accumulator (carry it like
    optimizer state through the jitted step)."""
    return jnp.zeros(bins, jnp.float32)


def accum_add(acc, idx, w=None):
    """One observation step: `acc.at[idx].add(w or 1)` — the
    `telemetry_update` segment-sum pattern.  Pure and jit-safe."""
    flat = jnp.reshape(jnp.asarray(idx), (-1,)).astype(jnp.int32)
    if w is None:
        return acc.at[flat].add(1.0)
    wf = jnp.reshape(jnp.asarray(w), (-1,)).astype(jnp.float32)
    return acc.at[flat].add(wf)


def hist_bucket_add(acc, values, bounds: Sequence[float]):
    """Device-side histogram step: bucket `values` by the static `bounds`
    (cumulative `le` semantics) and scatter-add into `acc`, which must
    have `len(bounds) + 1` slots (`accum_init(len(bounds) + 1)`).  Drain
    with `Histogram.merge_counts(np.asarray(acc))`."""
    v = jnp.reshape(jnp.asarray(values), (-1,)).astype(jnp.float32)
    b = jnp.searchsorted(jnp.asarray(bounds, jnp.float32), v, side="left")
    return acc.at[b].add(1.0)
