"""lram-repro: the E8-lattice differentiable memory layer (JAX/Pallas).

Package layout (full walkthrough in docs/architecture.md):

  * `repro.core`        — the paper's layer: lattice, torus, indexing, LRAM
  * `repro.quant`       — int8/fp8 value-table storage codec
  * `repro.kernels`     — Pallas TPU kernels + jnp references
  * `repro.memstore`    — tiered host/device value store
  * `repro.memctl`      — memory lifecycle: telemetry, growth, migration
  * `repro.distributed` — sharded lookup, pipeline, collectives, fault
  * `repro.nn`          — minimal functional NN substrate
  * `repro.optim`       — Adam (10x memory LR) + gradient compression
  * `repro.models`      — transformer/mamba/moe blocks hosting the layer
  * `repro.data`        — synthetic objectives (incl. fact recall)
  * `repro.configs`     — architecture registry
  * `repro.checkpoint`  — atomic, checksummed, shard-streaming
  * `repro.analysis`    — HLO collective parsing, roofline estimates
  * `repro.launch`      — train / serve / dryrun drivers

Subpackages import lazily from here on down — `import repro` pulls no jax.
"""
