"""Zamba2-2.7B — hybrid: Mamba2 blocks + one SHARED attention block invoked
every 6 mamba blocks [arXiv:2411.15242; hf].

Simplifications vs the HF checkpoint (noted in DESIGN.md §7): the shared
block's per-invocation LoRA adapters are dropped (pure parameter sharing),
and the shared block input is the residual stream (no concat with the
original embedding)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,     # MHA in the shared block
        d_ff=10240,
        vocab_size=32000,
        hybrid_pattern=6,    # 54 mamba layers -> 9 shared-attn invocations
        shared_attention=True,
        ssm_state=64,
        ssm_headdim=64,
        ssm_expand=2,
        ssm_groups=1,
        act="gelu",
        norm="layer",
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke",
        family="hybrid",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        hybrid_pattern=2,
        shared_attention=True,
        ssm_state=16,
        ssm_headdim=16,
        ssm_expand=2,
        ssm_groups=1,
        ssm_chunk=4,
        act="gelu",
        norm="layer",
        remat=False,
    )
