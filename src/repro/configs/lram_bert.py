"""The paper's own models (§3): 6-layer BERT-style MLM transformer, w=512,
with the 4th layer's FC subnetwork replaced by LRAM (or PKM).

Variants: baseline | pkm | small (2^18 slots) | medium (2^20) | large (2^22)
— paper Tables 2 & 5."""

import dataclasses

from repro.core import lram as lram_mod
from repro.core.pkm import PKMConfig
from repro.models.config import ModelConfig

_MEM_LAYER = 3  # "the fourth transformer layer" (0-indexed)

_LOG2 = {"small": 18, "medium": 20, "large": 22}


def _base(vocab: int = 30000, w: int = 512) -> ModelConfig:
    return ModelConfig(
        name="lram-bert-baseline",
        family="dense",
        num_layers=6,
        d_model=w,
        num_heads=8,
        num_kv_heads=8,
        d_ff=2048,           # hidden width 2048, GELU (paper §3.2)
        vocab_size=vocab,
        objective="mlm",
        pos_scheme="learned",
        max_seq=256,
        act="gelu",
        norm="layer",
        remat=False,
    )


def config(variant: str = "baseline") -> ModelConfig:
    cfg = _base()
    if variant == "baseline":
        return cfg
    if variant == "pkm":
        return dataclasses.replace(
            cfg,
            name="lram-bert-pkm",
            pkm_layers=(_MEM_LAYER,),
            pkm=PKMConfig(n_keys=256, heads=8, key_dim=64, value_dim=512,
                          top_k=32, query_norm="batch"),
        )
    log2 = _LOG2[variant]
    return dataclasses.replace(
        cfg,
        name=f"lram-bert-{variant}",
        lram_layers=(_MEM_LAYER,),
        lram=lram_mod.memffn_config(cfg.d_model, log2, query_norm="batch"),
    )


def smoke_config(variant: str = "baseline") -> ModelConfig:
    cfg = dataclasses.replace(
        _base(vocab=256, w=64),
        name=f"lram-bert-{variant}-smoke",
        num_layers=3,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        max_seq=64,
    )
    if variant == "baseline":
        return cfg
    if variant == "pkm":
        return dataclasses.replace(
            cfg,
            pkm_layers=(1,),
            pkm=PKMConfig(n_keys=16, heads=2, key_dim=16, value_dim=64,
                          top_k=4, query_norm="batch"),
        )
    return dataclasses.replace(
        cfg,
        lram_layers=(1,),
        lram=lram_mod.memffn_config(64, 16, query_norm="batch"),
    )
