"""H2O-Danube3-4B — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        num_layers=24,
        d_model=3840,
        num_heads=32,
        num_kv_heads=8,
        d_ff=10240,
        vocab_size=32000,
        attention="swa",
        window=4096,
        act="swiglu",
        norm="rms",
        rope_theta=1e4,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        attention="swa",
        window=8,
        act="swiglu",
        norm="rms",
        remat=False,
    )
