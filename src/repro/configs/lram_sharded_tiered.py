"""`lram-sharded-tiered`: row-range-sharded tiered memory.

The composition the lookup-plan registry unlocked: the value table is
split into `model_shards` contiguous row ranges (the model-parallel
ownership layout of `repro.distributed.sharded_lram`), and each range is
a host-offloaded tiered store with its own device hot cache
(`repro.memstore`).  Capacity therefore scales with the *sum* of the
owners' host memories — tables larger than any single host — while every
lookup stays O(1): each range contributes a masked partial interpolation
over only the rows it owns, joined by a partial-sum (the psum, when
ranges live on separate hosts).

Same model shape as `lram-tiered`; `interp_impl="sharded-tiered"` with
`model_shards` row ranges.  Write-back training, shard-streaming
checkpoints (byte-compatible with plain tiered checkpoints of the same
layout), and serve-loop prefetch all ride the per-range stores.
"""

from __future__ import annotations

import dataclasses

from repro.configs import lram_tiered


def _shard(cfg, ranges: int):
    return dataclasses.replace(
        cfg,
        name="lram-sharded-tiered",
        lram=dataclasses.replace(
            cfg.lram, interp_impl="sharded-tiered", model_shards=ranges
        ),
    )


def config():
    # 2^20 rows over 4 ranges: 32 shards of 8192 rows per range, each
    # range caching 8 slots (25% resident within its range)
    base = lram_tiered.config()
    return _shard(
        dataclasses.replace(
            base,
            lram=dataclasses.replace(
                base.lram,
                tiered=dataclasses.replace(
                    base.lram.tiered, cache_slots=8
                ),
            ),
        ),
        ranges=4,
    )


def smoke_config():
    # 2^16 rows over 2 ranges of 16 shards (2048 rows each); 4 cache
    # slots per range -> the table still exceeds the aggregate device
    # budget, the regime the tiered tests require
    base = lram_tiered.smoke_config()
    return _shard(
        dataclasses.replace(
            base,
            lram=dataclasses.replace(
                base.lram,
                tiered=dataclasses.replace(
                    base.lram.tiered, cache_slots=4
                ),
            ),
        ),
        ranges=2,
    )
