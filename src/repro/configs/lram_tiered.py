"""Tiered-memory LRAM arch: the paper's memory layer with the value table
host-offloaded behind a device hot cache (`interp_impl="tiered"`).

This is the capacity configuration the dense `lram-bert-*` variants cannot
reach: N is bounded by host RAM (or disk, with `backing="mmap"`), not HBM.
The full config keeps 2^20 locations with a 32-shard cache (25% resident);
the smoke config is sized so the table (16 MiB) exceeds the device-cache
budget (4 MiB) — the regime tier-1 tests and `benchmarks/table6_tiering.py`
exercise.  Causal-LM objective so the same config drives both
`repro.launch.train` and `repro.launch.serve`.  See docs/memstore.md.
"""

from __future__ import annotations

import dataclasses

from repro.core import lram as lram_mod
from repro.memstore import TieredSpec
from repro.models.config import ModelConfig


def _base(vocab: int, w: int, layers: int) -> ModelConfig:
    return ModelConfig(
        name="lram-tiered",
        family="dense",
        num_layers=layers,
        d_model=w,
        num_heads=max(4, w // 64),
        num_kv_heads=max(4, w // 64),
        d_ff=2 * w,
        vocab_size=vocab,
        objective="clm",
        # io_callback effects must run exactly once per step: no remat
        remat=False,
    )


def _with_tiered(cfg: ModelConfig, log2: int, spec: TieredSpec) -> ModelConfig:
    return dataclasses.replace(
        cfg,
        lram_layers=(cfg.num_layers // 2,),
        lram=lram_mod.memffn_config(
            cfg.d_model, log2, query_norm="batch",
            interp_impl="tiered", tiered=spec,
        ),
    )


def config() -> ModelConfig:
    # 2^20 x 64 f32 = 256 MiB table; cache 32/128 shards = 25% resident
    return _with_tiered(
        _base(vocab=30000, w=512, layers=6),
        log2=20,
        spec=TieredSpec(shard_rows=8192, cache_slots=32),
    )


def smoke_config() -> ModelConfig:
    # table: 2^16 x 64 f32 = 16 MiB in 32 shards; device budget: 8 slots
    # (4 MiB) -> N deliberately exceeds the cache, <50% resident
    return _with_tiered(
        _base(vocab=256, w=64, layers=2),
        log2=16,
        spec=TieredSpec(shard_rows=2048, cache_slots=8),
    )
