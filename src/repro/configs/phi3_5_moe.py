"""Phi-3.5-MoE (42B total / 6.6B active) — 16 experts, top-2
[hf:microsoft/Phi-3.5-MoE-instruct]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=6400,
        vocab_size=32064,
        num_experts=16,
        top_k_experts=2,
        attention="full",
        act="swiglu",
        norm="rms",
        rope_theta=1e4,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-smoke",
        family="moe",
        num_layers=3,
        d_model=48,
        num_heads=4,
        num_kv_heads=2,
        d_ff=64,
        vocab_size=256,
        num_experts=4,
        top_k_experts=2,
        act="swiglu",
        norm="rms",
        remat=False,
    )
