"""Architecture registry: one module per assigned arch + the paper's models.

Every module exposes `config()` (the exact published configuration) and
`smoke_config()` (a reduced same-family config for CPU smoke tests).
`get_config(name)` / `get_smoke_config(name)` dispatch by arch id; shapes
live in repro.configs.shapes.  Beyond-paper archs: `lram-tiered`
(host-offloaded value table), `lram-tiered-q8` (the same with int8
rows + per-row scales on both tiers), and `lram-sharded-tiered`
(row-range-sharded tiered memory: each model shard owns a host-offloaded
range with its own hot cache); `with_lram(cfg)` inserts the paper's
memory FFN into any registered arch.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.core.lram import LRAMConfig
from repro.core import lram as lram_mod
from repro.models.config import ModelConfig

ARCHS = (
    "yi-9b",
    "qwen2-1.5b",
    "starcoder2-3b",
    "h2o-danube-3-4b",
    "zamba2-2.7b",
    "phi3.5-moe-42b-a6.6b",
    "mixtral-8x7b",
    "mamba2-1.3b",
    "whisper-small",
    "qwen2-vl-72b",
)

PAPER_MODELS = (
    "lram-bert-baseline",
    "lram-bert-pkm",
    "lram-bert-small",
    "lram-bert-medium",
    "lram-bert-large",
)

# beyond-paper configs: registered for get_config()/launchers, but kept out
# of the per-arch smoke matrix (they have their own tier-1 coverage)
EXTRA_MODELS = ("lram-tiered", "lram-tiered-q8", "lram-sharded-tiered")

_MODULES = {
    "yi-9b": "yi_9b",
    "qwen2-1.5b": "qwen2_1_5b",
    "starcoder2-3b": "starcoder2_3b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "zamba2-2.7b": "zamba2_2_7b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-1.3b": "mamba2_1_3b",
    "whisper-small": "whisper_small",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "lram-bert-baseline": "lram_bert",
    "lram-bert-pkm": "lram_bert",
    "lram-bert-small": "lram_bert",
    "lram-bert-medium": "lram_bert",
    "lram-bert-large": "lram_bert",
    "lram-tiered": "lram_tiered",
    "lram-tiered-q8": "lram_tiered_q8",
    "lram-sharded-tiered": "lram_sharded_tiered",
}


# every registered module is reachable from exactly one of the three lists
assert set(_MODULES) == set(ARCHS) | set(PAPER_MODELS) | set(EXTRA_MODELS)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str, **overrides) -> ModelConfig:
    mod = _module(name)
    if name.startswith("lram-bert"):
        cfg = mod.config(variant=name.removeprefix("lram-bert-"))
    else:
        cfg = mod.config()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke_config(name: str, **overrides) -> ModelConfig:
    mod = _module(name)
    if name.startswith("lram-bert"):
        cfg = mod.smoke_config(variant=name.removeprefix("lram-bert-"))
    else:
        cfg = mod.smoke_config()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def with_lram(cfg: ModelConfig, log2_locations: int = 20,
              layer: int | None = None) -> ModelConfig:
    """Insert the paper's memory-augmented FFN at one layer of any arch."""
    layer = cfg.num_layers // 2 if layer is None else layer
    return dataclasses.replace(
        cfg,
        name=f"{cfg.name}+lram{log2_locations}",
        lram_layers=(layer,),
        lram=lram_mod.memffn_config(
            cfg.d_model, log2_locations, query_norm="batch"
        ),
    )
