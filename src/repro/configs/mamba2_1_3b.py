"""Mamba2-1.3B — pure SSM (state-space duality) [arXiv:2405.21060]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=16,        # unused (attention-free); keeps config valid
        num_kv_heads=16,
        d_ff=0,              # no FFN: the mamba mixer is the whole block
        vocab_size=50280,
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,        # d_inner 4096 -> 64 ssm heads
        ssm_groups=1,
        ssm_chunk=64,
        pos_scheme="none",
        norm="rms",
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-smoke",
        family="ssm",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=256,
        ssm_state=16,
        ssm_headdim=16,
        ssm_expand=2,
        ssm_groups=1,
        ssm_chunk=4,
        pos_scheme="none",
        norm="rms",
        remat=False,
    )
