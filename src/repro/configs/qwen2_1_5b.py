"""Qwen2-1.5B — dense GQA with QKV bias, tied embeddings [arXiv:2407.10671]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        attention="full",
        qkv_bias=True,
        tie_embeddings=True,
        act="swiglu",
        norm="rms",
        rope_theta=1e6,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b-smoke",
        family="dense",
        num_layers=3,
        d_model=48,
        num_heads=6,
        num_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        qkv_bias=True,
        tie_embeddings=True,
        act="swiglu",
        norm="rms",
        remat=False,
    )
