"""Whisper-small — encoder-decoder backbone; conv audio frontend is a STUB
(input_specs feeds precomputed frame embeddings) [arXiv:2212.04356]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="encdec",
        num_layers=12,        # decoder layers
        encoder_layers=12,
        encoder_len=1500,     # 30 s of audio at 50 Hz after the conv stub
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        pos_scheme="learned",
        max_seq=32768,        # decode_32k cell (mechanical; >> whisper's 448)
        act="gelu",
        norm="layer",
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke",
        family="encdec",
        num_layers=2,
        encoder_layers=2,
        encoder_len=12,
        d_model=48,
        num_heads=4,
        num_kv_heads=4,
        d_ff=96,
        vocab_size=256,
        pos_scheme="learned",
        max_seq=64,
        act="gelu",
        norm="layer",
        remat=False,
    )
