"""Yi-9B — llama-arch dense GQA [arXiv:2403.04652; hf]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b",
        family="dense",
        num_layers=48,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        d_ff=11008,
        vocab_size=64000,
        attention="full",
        act="swiglu",
        norm="rms",
        rope_theta=1e4,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        act="swiglu",
        norm="rms",
        remat=False,
    )
