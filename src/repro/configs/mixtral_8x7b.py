"""Mixtral-8x7B — 8 experts top-2, sliding-window attention
[arXiv:2401.04088]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        num_experts=8,
        top_k_experts=2,
        attention="swa",
        window=4096,
        act="swiglu",
        norm="rms",
        rope_theta=1e6,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke",
        family="moe",
        num_layers=3,
        d_model=48,
        num_heads=4,
        num_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        num_experts=4,
        top_k_experts=2,
        attention="swa",
        window=8,
        act="swiglu",
        norm="rms",
        remat=False,
    )
