"""Assigned input-shape set + ShapeDtypeStruct builders for the dry-run.

Every (arch x shape) pair is a dry-run cell:

  train_4k     seq 4096,    global_batch 256  -> train_step
  prefill_32k  seq 32768,   global_batch 32   -> prefill (forward + caches)
  decode_32k   seq 32768,   global_batch 128  -> decode_step (1 new token)
  long_500k    seq 524288,  global_batch 1    -> decode_step; only for
               sub-quadratic archs (SSM / hybrid / SWA) — see DESIGN.md §5
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig, validate_cell

Sds = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def skip_reason(cfg: ModelConfig, shape_name: str) -> str | None:
    return validate_cell(cfg, shape_name)


def _extras(cfg: ModelConfig, b: int, s: int) -> dict:
    """Modality-frontend STUBS: precomputed frame/patch embeddings."""
    extras = {}
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        extras["encoder_embeds"] = Sds((b, cfg.encoder_len, cfg.d_model), dt)
    if cfg.vision_tokens:
        extras["vision_embeds"] = Sds((b, cfg.vision_tokens, cfg.d_model), dt)
        extras["positions"] = Sds((3, b, s), jnp.int32)
    return extras


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the lowered step.

    train  -> {"batch": {tokens, labels, ...extras}}
    prefill-> {"batch": {tokens, ...extras}}
    decode -> {"tokens": (B,1), "cache": <full cache pytree>}
    """
    cell = SHAPES[shape_name]
    b, s = cell.global_batch, cell.seq_len
    if cell.mode == "train":
        batch = {
            "tokens": Sds((b, s), jnp.int32),
            "labels": Sds((b, s), jnp.int32),
        }
        batch.update(_extras(cfg, b, s))
        return {"batch": batch}
    if cell.mode == "prefill":
        batch = {"tokens": Sds((b, s), jnp.int32)}
        batch.update(_extras(cfg, b, s))
        return {"batch": batch}
    # decode: one new token against a cache of length s
    return {
        "tokens": Sds((b, 1), jnp.int32),
        "cache": transformer.cache_specs(cfg, b, s),
    }
