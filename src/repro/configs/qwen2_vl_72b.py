"""Qwen2-VL-72B — M-RoPE, dynamic-resolution vision (STUB: input_specs feeds
merged patch embeddings + 3D position ids) [arXiv:2409.12191]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=29568,
        vocab_size=152064,
        attention="full",
        qkv_bias=True,
        pos_scheme="mrope",
        mrope_sections=(16, 24, 24),
        vision_tokens=256,
        act="swiglu",
        norm="rms",
        rope_theta=1e6,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b-smoke",
        family="vlm",
        num_layers=3,
        d_model=48,
        num_heads=4,
        num_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        qkv_bias=True,
        pos_scheme="mrope",
        mrope_sections=(2, 2, 2),
        vision_tokens=4,
        act="swiglu",
        norm="rms",
        remat=False,
    )
