"""`lram-tiered-q8`: the tiered-memory LRAM with an int8 value table.

Same model and tiering layout as `lram-tiered`, with the host shards, the
device hot cache, and the host->device fill traffic all carrying 1-byte
rows plus per-row fp32 scales (`LRAMConfig.table_quant="int8"` /
`TieredSpec.quant`).  At the paper's m=64 that is 68 B/entry vs 256 —
a ~3.8x capacity multiplier at fixed memory budget, and the same factor
off every PCIe fill (benchmarks/table7_quant.py measures both).  Training
still works: the sparse write-back requantizes dirty rows with stochastic
rounding (see docs/memstore.md).
"""

from __future__ import annotations

import dataclasses

from repro.configs import lram_tiered


def _quantize(cfg):
    spec = dataclasses.replace(cfg.lram.tiered, quant="int8")
    return dataclasses.replace(
        cfg,
        name="lram-tiered-q8",
        lram=dataclasses.replace(cfg.lram, table_quant="int8", tiered=spec),
    )


def config():
    return _quantize(lram_tiered.config())


def smoke_config():
    return _quantize(lram_tiered.smoke_config())
