"""StarCoder2-3B — GQA, RoPE, GELU + LayerNorm, biases [arXiv:2402.19173]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        d_ff=12288,
        vocab_size=49152,
        attention="full",
        qkv_bias=True,
        mlp_bias=True,
        act="gelu",
        norm="layer",
        rope_theta=1e5,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke",
        family="dense",
        num_layers=3,
        d_model=48,
        num_heads=4,
        num_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        qkv_bias=True,
        mlp_bias=True,
        act="gelu",
        norm="layer",
        remat=False,
    )
