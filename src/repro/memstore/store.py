"""Tiered value table: host-RAM (or disk) shards + a device-resident hot cache.

The dense `LRAM` keeps the whole (N, m) value table in device memory, which
caps N at HBM size long before the paper's "billions of entries".  This
module splits the table into fixed-size *shards* of `shard_rows` consecutive
lattice-bucket rows:

    global row id  r  ->  shard  r >> log2(shard_rows)
                          row    r &  (shard_rows - 1)

  * **Host tier** — one `(num_shards, shard_rows, m)` ndarray in host RAM
    (`backing="ram"`), or an `np.memmap`-backed ``.npy`` on disk
    (`backing="mmap"`) for tables larger than host memory.
  * **Device tier** — `cache_slots` shard-sized slots in device memory plus
    an *indirection table* `shard -> slot` (-1 = not resident).  Lookups map
    (shard, row) through the indirection table and gather from the cache
    with a single device kernel (`repro.kernels.tiered_gather`, or jnp).
  * **Misses** are batched per lookup: all absent shards touched by a batch
    are copied host->device in one stacked `device_put` + scatter (JAX
    dispatch is async, so the copy overlaps the caller's next ops).
    `prefetch()` runs the same fill from a *predicted* index set — the serve
    loop feeds it the previous decode step's accesses so fills overlap the
    dense compute of the next step.
  * **Eviction** is LRU over shards, with the current batch's shards pinned
    so a fill can never evict a shard the same gather still needs.  If a
    single batch touches more distinct shards than there are slots, the
    overflow rows are served straight from the host tier (counted in
    `stats["uncached"]`) — correctness never depends on cache capacity.
  * **Training write-back**: gradients w.r.t. values arrive as sparse
    (index, w*g) pairs from the custom VJP (`repro.memstore.interp`) and are
    applied as a sparse SGD step (`writeback_lr`) directly to the cached
    copy, marking the slot *dirty*; dirty slots are written back to their
    host shard on eviction, `flush()`, or checkpoint save.  This mirrors how
    production embedding tables own their sparse optimizer step instead of
    routing the table through the dense Adam.
  * **Quantized storage** (`TieredSpec.quant` of int8 | fp8): both tiers
    hold 1-byte payload rows plus per-row fp32 scales (`repro.quant`), so
    host capacity, the device-cache budget, and every host->device fill
    shrink ~4x.  Gathers dequantize on device (the interpolation stays
    fp32); the write-back dequantizes touched rows, applies the update,
    and requantizes with **stochastic rounding** so sub-quantum updates
    survive in expectation.

See docs/memstore.md for the full design narrative and docs/architecture.md
for where this store sits among the four lookup paths.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import tempfile
import threading
import time
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, quant
from repro.core import lookup


@dataclasses.dataclass(frozen=True)
class TieredSpec:
    """Static configuration of a tiered table (hashable: rides LRAMConfig)."""

    shard_rows: int = 2048      # rows per shard (power of two)
    cache_slots: int = 32       # device-resident shards
    backing: str = "ram"        # ram | mmap
    backing_dir: str | None = None   # mmap only; default: a tempdir
    use_pallas: bool = False    # indirected-gather kernel vs jnp reference
    quant: str = "none"         # none | int8 | fp8: 1-byte rows + row scales

    def __post_init__(self):
        if self.shard_rows & (self.shard_rows - 1):
            raise ValueError("shard_rows must be a power of two")
        if self.cache_slots < 1:
            raise ValueError("need at least one cache slot")
        if self.backing not in ("ram", "mmap"):
            raise ValueError(f"unknown backing {self.backing!r}")
        if self.quant != "none":
            quant.check_kind(self.quant)


class TieredValueStore:
    """Host-offloaded (N, m) value table with a device-resident hot cache.

    Registered as a *leafless* pytree node, so it can sit at
    ``params["values"]`` and ride through jit/grad/optimizer tree maps
    untouched; `repro.checkpoint` detects it and streams shards to disk.
    """

    def __init__(self, num_rows: int, m: int, spec: TieredSpec,
                 *, dtype=np.float32):
        if num_rows % spec.shard_rows:
            raise ValueError(
                f"num_rows={num_rows} not divisible by "
                f"shard_rows={spec.shard_rows}"
            )
        self.spec = spec
        self.num_rows = num_rows
        self.m = m
        self.dtype = np.dtype(dtype)  # logical dtype (dequantized values)
        self.quant = spec.quant
        self.storage_dtype = (
            quant.storage_dtype(self.quant) if self.quant != "none"
            else self.dtype
        )
        self.shard_rows = spec.shard_rows
        self.num_shards = num_rows // spec.shard_rows
        self.cache_slots = min(spec.cache_slots, self.num_shards)
        self._log2R = self.shard_rows.bit_length() - 1

        self._host, self._host_scale = self._alloc_host()
        # device tier + indirection; quantized stores cache the 1-byte
        # payload + per-row scales, so the cache budget also shrinks ~4x
        self.cache_np = np.zeros(
            (self.cache_slots, self.shard_rows, m),
            self.storage_dtype if self.quant != "none" else np.float32,
        )
        self.cache_scale_np = (
            np.zeros((self.cache_slots, self.shard_rows), np.float32)
            if self.quant != "none" else None
        )
        self._cache_dev: jax.Array | None = None
        self._scale_dev: jax.Array | None = None
        # write-back requantization noise (stochastic rounding, int8)
        self._wb_rng = np.random.default_rng(0)
        self._shard_slot = np.full(self.num_shards, -1, np.int32)
        self._slot_shard = np.full(self.cache_slots, -1, np.int32)
        self._lru: collections.OrderedDict[int, int] = collections.OrderedDict()
        self._free = list(range(self.cache_slots - 1, -1, -1))
        self._dirty: set[int] = set()
        self._dev_stale: set[int] = set()

        # training write-back (sparse SGD; set by the trainer)
        self.writeback_lr = 0.0
        self.last_access: np.ndarray | None = None

        self._traced_interp = None  # built lazily by repro.memstore.interp
        # per-shard access counts (usage telemetry, repro.memctl): unlike
        # `stats`, indexed by shard so dead/hot regions are localizable
        self.shard_access = np.zeros(self.num_shards, np.int64)
        # guards cache residency + stat counters: fills run on the
        # prefetch worker pool (ShardedTieredStore fan-out) and lookup
        # callbacks run on XLA's io_callback threads, so every mutator of
        # `stats` / LRU / cache mirrors below takes this re-entrant lock.
        # Readers of individual stat values stay lock-free (a single dict
        # read is atomic); only read-modify-write needs the guard.
        self._lock = threading.RLock()
        self.reset_stats()

    # ------------------------------------------------------------------ init

    def _alloc_host(self) -> tuple[np.ndarray, np.ndarray | None]:
        shape = (self.num_shards, self.shard_rows, self.m)
        sshape = shape[:-1]
        vdtype = self.storage_dtype
        if self.spec.backing == "ram":
            values = np.zeros(shape, vdtype)
            scales = (np.zeros(sshape, np.float32)
                      if self.quant != "none" else None)
            return values, scales
        d = self.spec.backing_dir or tempfile.mkdtemp(prefix="memstore_")
        os.makedirs(d, exist_ok=True)
        path = os.path.join(d, f"values_{self.num_rows}x{self.m}.npy")
        values = np.lib.format.open_memmap(
            path, mode="w+", dtype=vdtype, shape=shape
        )
        scales = None
        if self.quant != "none":
            spath = os.path.join(d, f"scales_{self.num_rows}x{self.m}.npy")
            scales = np.lib.format.open_memmap(
                spath, mode="w+", dtype=np.float32, shape=sshape
            )
        return values, scales

    @classmethod
    def from_dense(cls, values: np.ndarray, spec: TieredSpec,
                   **kw) -> "TieredValueStore":
        values = np.asarray(values)
        n, m = values.shape
        dtype = values.dtype if spec.quant == "none" else np.float32
        store = cls(n, m, spec, dtype=dtype, **kw)
        store._fill_host(values)
        return store

    def _fill_host(self, values: np.ndarray) -> None:
        shaped = values.reshape(self.num_shards, self.shard_rows, self.m)
        if self.quant == "none":
            self._host[...] = shaped
        else:
            # nearest rounding here (init / load): identical to the dense
            # QuantizedTable built from the same draw
            q, s = quant.quantize_rows_np(shaped, self.quant)
            self._host[...] = q
            self._host_scale[...] = s

    def to_dense(self) -> np.ndarray:
        """Flush dirty slots and materialize the full (dequantized) table."""
        self.flush()
        if self.quant == "none":
            return np.array(self._host).reshape(self.num_rows, self.m)
        return quant.dequantize_rows_np(
            np.asarray(self._host), np.asarray(self._host_scale)
        ).reshape(self.num_rows, self.m)

    def load_dense(self, values: np.ndarray) -> None:
        """Replace table contents; invalidates the cache."""
        values = np.asarray(values)
        if values.shape != (self.num_rows, self.m):
            raise ValueError(
                f"shape {values.shape} != {(self.num_rows, self.m)}"
            )
        self._invalidate_cache()
        self._fill_host(values)

    def _invalidate_cache(self) -> None:
        with self._lock:
            self._shard_slot[:] = -1
            self._slot_shard[:] = -1
            self._lru.clear()
            self._free = list(range(self.cache_slots - 1, -1, -1))
            self._dirty.clear()
            self._dev_stale.clear()
            self._cache_dev = None
            self._scale_dev = None

    # ----------------------------------------------------------- addressing

    def _split(self, flat_idx: np.ndarray):
        flat_idx = flat_idx.astype(np.int64)
        return flat_idx >> self._log2R, flat_idx & (self.shard_rows - 1)

    # -------------------------------------------------- residency / mapping

    def _ensure_resident(self, shards: Iterable[int]) -> None:
        """Make `shards` cache-resident where capacity allows (LRU evict,
        current request pinned).  Fills update the host-side cache mirror
        and mark slots for the next batched device sync."""
        pinned = set(int(s) for s in shards)
        t0 = time.perf_counter()
        fills = evictions = 0
        with self._lock:
            for s in sorted(pinned):
                if self._shard_slot[s] >= 0:  # hit: touch
                    self._lru.move_to_end(s)
                    continue
                if self._free:
                    slot = self._free.pop()
                else:
                    victim = next(
                        (sh for sh in self._lru if sh not in pinned), None
                    )
                    if victim is None:  # whole cache pinned by this batch
                        continue
                    slot = self._lru.pop(victim)
                    self._writeback_slot(slot)
                    self._shard_slot[victim] = -1
                    self.stats["evictions"] += 1
                    evictions += 1
                self.cache_np[slot] = self._host[s]
                if self.quant != "none":
                    self.cache_scale_np[slot] = self._host_scale[s]
                self._shard_slot[s] = slot
                self._slot_shard[slot] = s
                self._lru[s] = slot
                self._lru.move_to_end(s)
                self._dev_stale.add(slot)
                self.stats["fills"] += 1
                fills += 1
        if fills:
            obs.counter("memstore.fills").inc(fills)
            obs.histogram("memstore.fill_s").observe(
                time.perf_counter() - t0
            )
        if evictions:
            obs.counter("memstore.evictions").inc(evictions)

    def _map(self, flat_idx: np.ndarray, *, count: bool = True,
             valid_elems: int | None = None):
        """(shard, row, slot, resident_mask) for flat global row ids,
        servicing misses along the way.  `valid_elems` limits the stat
        counting to the leading prefix — callers that pad a batch to a
        compile bucket (weight-0 duplicates) must not inflate
        hits/misses/uncached with phantom accesses."""
        shard, row = self._split(flat_idx)
        resident_before = self._shard_slot[shard] >= 0
        self._ensure_resident(np.unique(shard))
        slot = self._shard_slot[shard]
        mask = slot >= 0
        if count:
            v = slice(None) if valid_elems is None else slice(0, valid_elems)
            hits = int(resident_before[v].sum())
            misses = int((~resident_before[v] & mask[v]).sum())
            uncached = int((~mask[v]).sum())
            with self._lock:
                self.last_access = flat_idx  # feeds prefetch_last()
                self.stats["lookups"] += 1
                self.stats["hits"] += hits
                self.stats["misses"] += misses
                self.stats["uncached"] += uncached
                np.add.at(self.shard_access, shard[v], 1)
            obs.counter("memstore.hits").inc(hits)
            obs.counter("memstore.misses").inc(misses)
            obs.counter("memstore.uncached").inc(uncached)
        return shard, row, slot.astype(np.int64), mask

    def prefetch(self, idx, *, sync_device: bool = True) -> None:
        """Warm the cache for a predicted index set (e.g. the previous decode
        step's accesses) without touching hit/miss stats; the device copy is
        dispatched asynchronously and overlaps the caller's compute.
        `sync_device=False` fills only the host-side cache mirror — the
        right mode when the consumer is the traced (io_callback) lookup,
        which reads `cache_np`; the device mirror then syncs lazily on the
        next eager gather."""
        flat = np.asarray(idx).reshape(-1)
        shard, _ = self._split(flat)
        self._ensure_resident(np.unique(shard))
        if sync_device:
            self._sync_device()

    def prefetch_last(self, *, sync_device: bool = False) -> None:
        """Prefetch from the previous lookup's accesses — the serve loop's
        next-step predictor (decode locality).  Refreshes those shards to
        MRU and re-attempts fills for any that overflowed or were evicted,
        so the fill overlaps the next step's dense compute.  Defaults to
        host-mirror-only: the jitted decode path gathers via io_callback
        from `cache_np`, so an eager device upload here would be traffic
        nothing consumes."""
        if self.last_access is not None:
            self.prefetch(self.last_access, sync_device=sync_device)

    def warm(self, shards: Iterable[int] | None = None) -> None:
        """Fill the cache ahead of serving (default: lowest-id shards)."""
        if shards is None:
            shards = range(self.cache_slots)
        self._ensure_resident(shards)
        self._sync_device()

    # ------------------------------------------------------- device mirror

    def _sync_device(self) -> None:
        t0 = time.perf_counter()
        with self._lock:
            if self._cache_dev is None:
                self._cache_dev = jnp.asarray(self.cache_np)
                synced = self.cache_np.nbytes
                if self.quant != "none":
                    self._scale_dev = jnp.asarray(self.cache_scale_np)
                    synced += self.cache_scale_np.nbytes
                self._dev_stale.clear()
                self.stats["fill_bytes"] += synced
            elif not self._dev_stale:
                return
            else:
                slots = np.fromiter(sorted(self._dev_stale), np.int32)
                # one stacked host->device copy
                block = jnp.asarray(self.cache_np[slots])
                self._cache_dev = self._cache_dev.at[
                    jnp.asarray(slots)
                ].set(block)
                synced = self.cache_np[slots].nbytes
                if self.quant != "none":
                    sblock = jnp.asarray(self.cache_scale_np[slots])
                    self._scale_dev = self._scale_dev.at[
                        jnp.asarray(slots)
                    ].set(sblock)
                    synced += self.cache_scale_np[slots].nbytes
                self._dev_stale.clear()
                self.stats["fill_bytes"] += synced
        obs.counter("memstore.fill_bytes").inc(synced)
        obs.histogram("memstore.device_sync_s").observe(
            time.perf_counter() - t0
        )

    @property
    def cache_dev(self) -> jax.Array:
        self._sync_device()
        return self._cache_dev

    @property
    def cache_scale_dev(self) -> jax.Array:
        self._sync_device()
        return self._scale_dev

    # ------------------------------------------------------------- lookups

    def gather(self, idx, w, *, valid_elems: int | None = None) -> jax.Array:
        """sum_k w[..., k] * values[idx[..., k]] -> (..., m), gathering from
        the device-resident cache (misses are filled first; rows of shards
        that cannot fit are appended from the host tier).  `valid_elems`:
        see `_map` — stat counting for bucket-padded batches."""
        idx_np = np.asarray(idx)
        lead, top_k = idx_np.shape[:-1], idx_np.shape[-1]
        flat = idx_np.reshape(-1)
        shard, row, slot, mask = self._map(flat, valid_elems=valid_elems)
        slot_rows = np.where(mask, slot * self.shard_rows + row, 0)
        quantized = self.quant != "none"
        cache_flat = self.cache_dev.reshape(-1, self.m)
        scale_flat = (self.cache_scale_dev.reshape(-1) if quantized
                      else None)
        table, scales = cache_flat, scale_flat
        if not mask.all():
            inv = ~mask
            ovf = self._host[shard[inv], row[inv]]
            slot_rows[inv] = cache_flat.shape[0] + np.arange(len(ovf))
            # pad the overflow block to a power-of-two bucket: the jitted
            # gather then sees O(log batch) distinct table shapes, not one
            # fresh XLA compile per distinct uncached-row count
            pad = 1 << max(0, (len(ovf) - 1)).bit_length()
            block = np.zeros((pad, self.m), self.cache_np.dtype)
            block[:len(ovf)] = ovf
            table = jnp.concatenate([cache_flat, jnp.asarray(block)], axis=0)
            if quantized:  # overflow rows stay 1-byte: scales ride along
                sblock = np.zeros((pad,), np.float32)
                sblock[:len(ovf)] = self._host_scale[shard[inv], row[inv]]
                scales = jnp.concatenate(
                    [scale_flat, jnp.asarray(sblock)], axis=0
                )
        w_flat = jnp.asarray(w).reshape(-1, top_k).astype(jnp.float32)
        sr = jnp.asarray(slot_rows.reshape(-1, top_k).astype(np.int32))
        if self.spec.use_pallas and mask.all():
            interpret = jax.default_backend() != "tpu"
            idx_dev = jnp.asarray(flat.reshape(-1, top_k).astype(np.int32))
            slot_dev = jnp.asarray(self._shard_slot)
            if quantized:
                kernel = lookup.kernel_gather("pallas", "tiered-quant")
                out = kernel(
                    cache_flat, scale_flat, idx_dev, slot_dev, w_flat,
                    shard_rows=self.shard_rows, interpret=interpret,
                )
            else:
                kernel = lookup.kernel_gather("pallas", "tiered")
                out = kernel(
                    cache_flat, idx_dev, slot_dev, w_flat,
                    shard_rows=self.shard_rows, interpret=interpret,
                )
        elif quantized:
            out = _gather_rows_device_quant(table, scales, sr, w_flat)
        else:
            out = _gather_rows_device(table, sr, w_flat)
        return out.reshape(*lead, self.m)

    def gather_rows_host(self, idx) -> np.ndarray:
        """values[idx] -> (idx.shape + (m,)) float32, via the same cache
        machinery but reading the host-side cache mirror.  This is the
        io_callback body used when the lookup runs inside jit/grad."""
        idx_np = np.asarray(idx)
        flat = idx_np.reshape(-1)
        shard, row, slot, mask = self._map(flat)
        rows = np.empty((flat.size, self.m), np.float32)
        if self.quant != "none":
            scales = np.empty((flat.size,), np.float32)
            if mask.any():
                rows[mask] = self.cache_np[slot[mask], row[mask]]
                scales[mask] = self.cache_scale_np[slot[mask], row[mask]]
            if not mask.all():
                inv = ~mask
                rows[inv] = self._host[shard[inv], row[inv]]
                scales[inv] = self._host_scale[shard[inv], row[inv]]
            rows *= scales[:, None]  # dequant: callback contract is fp32
        else:
            if mask.any():
                rows[mask] = self.cache_np[slot[mask], row[mask]]
            if not mask.all():
                inv = ~mask
                rows[inv] = self._host[shard[inv], row[inv]]
        return rows.reshape(*idx_np.shape, self.m)

    # ------------------------------------------------------------ training

    def apply_writeback(self, idx, wg) -> None:
        """Sparse SGD write-back: values[idx] -= writeback_lr * wg.

        `wg` is w ⊗ dL/dout from the custom VJP (dL/dvalues restricted to
        the touched rows).  Cached rows are updated in the cache (slot goes
        dirty); rows of non-resident shards update the host tier directly."""
        if self.writeback_lr <= 0.0:
            return
        idx_np = np.asarray(idx)
        flat = idx_np.reshape(-1)
        upd = -self.writeback_lr * np.asarray(wg, np.float32).reshape(
            -1, self.m
        )
        with self._lock:
            if self.quant != "none":
                self._apply_writeback_quant(flat, upd)
                self.stats["writebacks"] += 1
            else:
                shard, row = self._split(flat)
                slot = self._shard_slot[shard].astype(np.int64)
                mask = slot >= 0
                if mask.any():
                    np.add.at(
                        self.cache_np, (slot[mask], row[mask]), upd[mask]
                    )
                    touched = set(np.unique(slot[mask]).tolist())
                    self._dirty |= touched
                    self._dev_stale |= touched
                if not mask.all():
                    inv = ~mask
                    np.add.at(
                        self._host, (shard[inv], row[inv]),
                        upd[inv].astype(self._host.dtype),
                    )
                self.stats["writebacks"] += 1
        obs.counter("memstore.writebacks").inc()

    def _apply_writeback_quant(self, flat: np.ndarray,
                               upd: np.ndarray) -> None:
        """Quantization-aware sparse step: dequantize each touched row,
        apply the accumulated update, requantize with a fresh per-row scale
        and **stochastic rounding** (int8; `repro.quant`) so updates smaller
        than one quantization step survive in expectation — the same
        error-containment idea as the int8 gradient codec in
        `repro.optim.compression`, applied at the storage boundary."""
        uniq, inv = np.unique(flat, return_inverse=True)
        acc = np.zeros((len(uniq), self.m), np.float32)
        np.add.at(acc, inv, upd)  # duplicate indices accumulate first
        shard, row = self._split(uniq)
        slot = self._shard_slot[shard].astype(np.int64)
        mask = slot >= 0
        rng = self._wb_rng if self.quant == "int8" else None
        if mask.any():
            sl, rw = slot[mask], row[mask]
            cur = quant.dequantize_rows_np(
                self.cache_np[sl, rw], self.cache_scale_np[sl, rw]
            )
            q, s = quant.quantize_rows_np(
                cur + acc[mask], self.quant, rng=rng
            )
            self.cache_np[sl, rw] = q
            self.cache_scale_np[sl, rw] = s
            touched = set(np.unique(sl).tolist())
            self._dirty |= touched
            self._dev_stale |= touched
        if not mask.all():
            nm = ~mask
            sh, rw = shard[nm], row[nm]
            cur = quant.dequantize_rows_np(
                self._host[sh, rw], self._host_scale[sh, rw]
            )
            q, s = quant.quantize_rows_np(
                cur + acc[nm], self.quant, rng=rng
            )
            self._host[sh, rw] = q
            self._host_scale[sh, rw] = s

    def _flush_slot_to_host(self, slot: int) -> None:
        shard = self._slot_shard[slot]
        if self.quant != "none":
            self._host[shard] = self.cache_np[slot]
            self._host_scale[shard] = self.cache_scale_np[slot]
        else:
            self._host[shard] = self.cache_np[slot].astype(self.dtype)

    def _writeback_slot(self, slot: int) -> None:
        if slot in self._dirty:
            self._flush_slot_to_host(slot)
            self._dirty.discard(slot)
            self.stats["dirty_writebacks"] += 1

    def flush(self) -> None:
        """Write every dirty cached shard back to its host shard."""
        with self._lock:
            for slot in sorted(self._dirty):
                self._flush_slot_to_host(slot)
                self.stats["dirty_writebacks"] += 1
            self._dirty.clear()

    # ---------------------------------------------------------- checkpoint

    def shard_host(self, i: int) -> np.ndarray:
        """Shard `i`'s stored payload as seen through the cache (dirty slots
        win).  Quantized stores return the 1-byte payload; its scales come
        from `shard_scale_host`."""
        slot = int(self._shard_slot[i])
        if slot >= 0 and slot in self._dirty:
            if self.quant != "none":
                return np.asarray(self.cache_np[slot])
            return self.cache_np[slot].astype(self.dtype)
        return np.asarray(self._host[i])

    def shard_scale_host(self, i: int) -> np.ndarray:
        """Per-row fp32 scales of shard `i` (quantized stores only)."""
        assert self.quant != "none"
        slot = int(self._shard_slot[i])
        if slot >= 0 and slot in self._dirty:
            return np.asarray(self.cache_scale_np[slot])
        return np.asarray(self._host_scale[i])

    def load_shard(self, i: int, arr: np.ndarray,
                   scale: np.ndarray | None = None) -> None:
        """Replace shard `i`.  `arr` may be fp values (requantized on the
        way in if this store is quantized) or a 1-byte payload with its
        per-row `scale` (dequantized if this store is dense) — this is what
        makes quantized<->dense checkpoint restore work shard by shard."""
        if arr.shape != (self.shard_rows, self.m):
            raise ValueError(
                f"shard {i}: shape {arr.shape} != "
                f"{(self.shard_rows, self.m)}"
            )
        if scale is not None and arr.dtype.itemsize != 1:
            raise ValueError("scale given but payload is not quantized")
        if self.quant != "none":
            if scale is None:  # fp input: quantize (nearest) on the way in
                q, s = quant.quantize_rows_np(
                    np.asarray(arr, np.float32), self.quant
                )
            elif arr.dtype != self.storage_dtype:  # cross-kind: requantize
                q, s = quant.quantize_rows_np(
                    quant.dequantize_rows_np(arr, scale), self.quant
                )
            else:
                q, s = arr, np.asarray(scale, np.float32)
            self._host[i] = q
            self._host_scale[i] = s
            slot = int(self._shard_slot[i])
            if slot >= 0:  # refresh the cached copy too
                self.cache_np[slot] = q
                self.cache_scale_np[slot] = s
                self._dirty.discard(slot)
                self._dev_stale.add(slot)
            return
        if scale is not None:  # quantized checkpoint into a dense store
            arr = quant.dequantize_rows_np(arr, scale)
        self._host[i] = arr.astype(self.dtype)
        slot = int(self._shard_slot[i])
        if slot >= 0:  # refresh the cached copy too
            self.cache_np[slot] = arr.astype(np.float32)
            self._dirty.discard(slot)
            self._dev_stale.add(slot)

    # ------------------------------------------------------------- lifecycle

    def _read_rows_raw(self, rows: np.ndarray):
        """(payload, scales|None) for global row ids, in *storage* form —
        1-byte payload + per-row scales for quantized stores, fp values
        otherwise.  Reads the host tier (dirty cache slots flushed first),
        without touching cache residency, LRU order, or stats: this is the
        bulk-copy path growth and migration use, not a lookup."""
        self.flush()
        shard, row = self._split(np.asarray(rows).reshape(-1))
        payload = np.asarray(self._host[shard, row])
        scales = (np.asarray(self._host_scale[shard, row])
                  if self.quant != "none" else None)
        return payload, scales

    def grow_rows(self, new_num_rows: int, parents: np.ndarray) -> None:
        """Append rows [num_rows, new_num_rows), each initialised from its
        (old-table) parent row id in `parents` — in place.

        Growth is append-only by construction (`repro.core.indexing.
        grow_torus` preserves every old flat index), so the existing host
        shards keep their ids and the device cache — slots, shard→slot
        indirection, LRU order, dirty flags — stays valid untouched: the
        pause is one host-side copy, no device traffic.  Quantized stores
        copy parent payload + per-row scale verbatim, so pre-growth
        lookups reproduce bit-exactly for every storage kind.  The cache
        slot count is left as built (`TieredSpec.cache_slots` already caps
        it); appended shards simply compete for the same slots.
        """
        delta = new_num_rows - self.num_rows
        if delta <= 0 or delta % self.shard_rows:
            raise ValueError(
                f"new_num_rows={new_num_rows} must exceed {self.num_rows} "
                f"by a multiple of shard_rows={self.shard_rows}"
            )
        parents = np.asarray(parents, np.int64).reshape(-1)
        if parents.size != delta:
            raise ValueError(
                f"need {delta} parent rows, got {parents.size}"
            )
        if parents.size and (parents.min() < 0
                             or parents.max() >= self.num_rows):
            raise ValueError("parent row ids must index the old table")
        with self._lock:
            payload, scales = self._read_rows_raw(parents)
            new_shards = delta // self.shard_rows
            pay3 = payload.reshape(new_shards, self.shard_rows, self.m)
            sc2 = (scales.reshape(new_shards, self.shard_rows)
                   if scales is not None else None)
            old_host, old_scale = self._host, self._host_scale
            old_n_shards = self.num_shards
            self.num_rows = new_num_rows
            self.num_shards += new_shards
            if self.spec.backing == "ram":
                self._host = np.concatenate([old_host, pay3])
                if self.quant != "none":
                    self._host_scale = np.concatenate([old_scale, sc2])
            else:  # mmap: a fresh file at the new shape (name encodes rows)
                self._host, self._host_scale = self._alloc_host()
                self._host[:old_n_shards] = old_host
                self._host[old_n_shards:] = pay3
                if self.quant != "none":
                    self._host_scale[:old_n_shards] = old_scale
                    self._host_scale[old_n_shards:] = sc2
            self._shard_slot = np.concatenate([
                self._shard_slot, np.full(new_shards, -1, np.int32)
            ])
            self.shard_access = np.concatenate([
                self.shard_access, np.zeros(new_shards, np.int64)
            ])
            self.last_access = None  # old ids stay valid, but re-prime

    def row_stats(self) -> tuple[np.ndarray, int]:
        """(per-shard access counts, rows per shard) — the store-side input
        to `repro.memctl.telemetry` (coarse: one bin per host shard)."""
        return self.shard_access.copy(), self.shard_rows

    # --------------------------------------------------------------- stats

    def reset_stats(self) -> None:
        with self._lock:
            self.shard_access[:] = 0
            self.stats = {
                "lookups": 0, "hits": 0, "misses": 0, "uncached": 0,
                "fills": 0, "evictions": 0, "writebacks": 0,
                "dirty_writebacks": 0, "fill_bytes": 0,
            }

    def bytes_per_entry(self) -> int:
        """Host-tier storage bytes per table row (payload + scale)."""
        if self.quant == "none":
            return self.m * self.dtype.itemsize
        return quant.bytes_per_entry(self.m, self.quant)

    def hit_rate(self) -> float:
        total = self.stats["hits"] + self.stats["misses"] \
            + self.stats["uncached"]
        return self.stats["hits"] / total if total else 0.0

    def resident_shards(self) -> list[int]:
        """Shards currently cached, least- to most-recently used."""
        return list(self._lru)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"TieredValueStore(rows={self.num_rows}, m={self.m}, "
            f"shards={self.num_shards}x{self.shard_rows}, "
            f"slots={self.cache_slots}, backing={self.spec.backing!r}, "
            f"quant={self.quant!r}, hit_rate={self.hit_rate():.3f})"
        )


@jax.jit
def _gather_rows_device(table, slot_rows, w):
    """rows = table[slot_rows]; out = einsum('nk,nkm->nm', w, rows)."""
    rows = jnp.take(table, slot_rows, axis=0)
    return jnp.einsum("nk,nkm->nm", w, rows)


@jax.jit
def _gather_rows_device_quant(table_q, table_scale, slot_rows, w):
    """Quantized twin: rows are gathered in 1-byte form, dequantized by the
    gathered per-row scales, and interpolated in fp32 — folding the scale
    into the weights so no (n, k, m) fp32 row tensor is materialized."""
    rows = jnp.take(table_q, slot_rows, axis=0)  # (n, k, m) int8/fp8
    ws = w * jnp.take(table_scale, slot_rows, axis=0)
    return jnp.einsum("nk,nkm->nm", ws, rows.astype(jnp.float32))


# Leafless pytree node: tree maps (grad, optimizer, sharding, jit flattening)
# pass the store through by aux-data identity without ever touching it.
jax.tree_util.register_pytree_node(
    TieredValueStore,
    lambda s: ((), s),
    lambda aux, children: aux,
)
lookup.register_store_type(TieredValueStore)


def find_stores(tree) -> list[tuple[str, TieredValueStore]]:
    """(path, store) for every distinct offloaded store in a pytree.

    Thin delegation to `repro.core.lookup.find_stores`, which walks the
    registered store types (TieredValueStore here, ShardedTieredStore in
    repro.distributed.sharded_lram)."""
    return lookup.find_stores(tree)
