"""The tiered placement backend: differentiable host-offloaded lookup.

Registers the `"tiered"` placement with the lookup-plan registry
(`repro.core.lookup`), so `interp_impl="tiered"` resolves to a plan whose
table is a `TieredValueStore` and whose interp is `tiered_interp` below.
The same entry point also drives the sharded-tiered placement
(`repro.distributed.sharded_lram.ShardedTieredStore` routes the per-range
cache walks behind the identical `gather` / `gather_rows_host` /
`apply_writeback` surface).

Two execution modes behind one entry point, `tiered_interp(store, idx, w)`:

  * **eager** (serve prefill, benchmarks, tests): concrete index arrays —
    cache fills are real stacked host->device copies and the gather runs on
    the device-resident cache (`TieredValueStore.gather`).
  * **traced** (jitted train step / decode step): the index array is a
    tracer, so the cache walk happens in `jax.experimental.io_callback`
    bodies.  Forward gathers the touched rows through the store (ordered —
    cache state mutates); backward emits the analytic dL/dw on device and
    hands the sparse dL/dvalues (w ⊗ g per touched row) to the store's
    write-back, which applies the sparse SGD step and marks shards dirty.

The custom VJP mirrors `repro.kernels.ops.lram_lookup`'s backward contract:
d values is the paper's sparse scatter-add (here: host-side into tiered
shards), d w is the gathered-row dot.  Query gradients keep flowing through
`w` exactly as in the dense reference path, so swapping a model between
dense and tiered changes *where the table lives*, not its gradients.

Quantized stores (`TieredSpec.quant`) need no special casing here: the
forward callback (`gather_rows_host`) hands back already-dequantized fp32
rows, and the backward's (index, w (x) g) pairs feed `apply_writeback`,
which requantizes dirty rows with stochastic rounding (see
repro.memstore.store / repro.quant).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from repro.core import lookup
from repro.memstore.store import TieredValueStore


# On few-core CPU hosts, force synchronous dispatch.  io_callback bodies run
# on the CPU client's executor threads; with async dispatch, materialising the
# callback's own operands (np.asarray(idx)) waits on a device_put that needs
# the very thread the callback occupies — a hard deadlock when the pool has no
# spare thread (reproduced on 1-cpu hosts: jit(grad) of tiered_interp never
# returns).  The flag is latched when the CPU client is built, so it must be
# set at import time — before the first jax computation — and it only affects
# the cpu backend, so setting it under an accelerator is harmless.
if (os.cpu_count() or 1) <= 2:
    jax.config.update("jax_cpu_enable_async_dispatch", False)


def tiered_interp(store, idx: jax.Array, w: jax.Array) -> jax.Array:
    """sum_k w[..., k] * store[idx[..., k]] -> (..., m); differentiable.

    `store` is a TieredValueStore or any object with the same
    gather / gather_rows_host / apply_writeback surface (the
    sharded-tiered range store)."""
    if isinstance(idx, jax.core.Tracer) or isinstance(w, jax.core.Tracer):
        if store._traced_interp is None:
            store._traced_interp = _build_traced_interp(store)
        return store._traced_interp(idx, w)
    return store.gather(idx, w)


def _build_traced_interp(store):
    m = store.m

    def _rows_cb(idx):
        return store.gather_rows_host(np.asarray(idx))

    def _writeback_cb(idx, wg):
        store.apply_writeback(np.asarray(idx), np.asarray(wg))
        return np.int32(0)

    def _rows(idx):
        shape = jax.ShapeDtypeStruct(tuple(idx.shape) + (m,), jnp.float32)
        # ordered: the callback mutates cache state (LRU, fills, stats)
        return io_callback(_rows_cb, shape, idx, ordered=True)

    @jax.custom_vjp
    def interp(idx, w):
        rows = _rows(idx)
        return jnp.einsum("...k,...km->...m", w.astype(jnp.float32), rows)

    def _fwd(idx, w):
        rows = _rows(idx)
        out = jnp.einsum("...k,...km->...m", w.astype(jnp.float32), rows)
        return out, (idx, w, rows)

    def _bwd(res, g):
        idx, w, rows = res
        g = g.astype(jnp.float32)
        dw = jnp.einsum("...m,...km->...k", g, rows)
        wg = w.astype(jnp.float32)[..., None] * g[..., None, :]
        token = io_callback(
            _writeback_cb, jax.ShapeDtypeStruct((), jnp.int32),
            idx, wg, ordered=True,
        )
        # tie the write-back effect into the returned cotangent
        dw = dw + jnp.zeros((), dw.dtype) * token.astype(dw.dtype)
        return (
            np.zeros(idx.shape, dtype=jax.dtypes.float0),
            dw.astype(w.dtype),
        )

    interp.defvjp(_fwd, _bwd)
    return interp


# ---------------------------------------------------------------------------
# the "tiered" placement backend (repro.core.lookup)
# ---------------------------------------------------------------------------

def _tiered_factory(cfg, storage: str, kernel: str) -> lookup.LookupPlan:
    spec = lookup.merged_tiered_spec(cfg, storage, kernel)
    if cfg.num_locations % spec.shard_rows:
        raise lookup.LookupPlanError(
            "tiered", storage, kernel,
            f"num_locations={cfg.num_locations} not divisible by "
            f"TieredSpec.shard_rows={spec.shard_rows}",
        )

    def build_table(dense):
        return TieredValueStore.from_dense(np.asarray(dense), spec)

    def interp(values, idx, w):
        if not isinstance(values, TieredValueStore):
            raise lookup.LookupPlanError(
                "tiered", storage, kernel,
                "params['values'] must be a TieredValueStore — init the "
                "layer with LRAMConfig(interp_impl='tiered')",
            )
        return tiered_interp(values, idx, w)

    return lookup.LookupPlan(
        placement="tiered", storage=storage, kernel=kernel,
        build_table=build_table, interp=interp,
        supports_prefetch=True, table_update="writeback",
        checkpoint_layout="shards",
        supports_growth=True, row_stats=True,
        build_empty=lambda: TieredValueStore(
            cfg.num_locations, cfg.m, spec
        ),
        supports_overlay=True,
    )


lookup.register_placement("tiered", _tiered_factory)
