"""Tiered memory store: host-offloaded value tables + device hot cache.

Capacity past device memory for the LRAM value table (paper: "billions of
entries"): shard the (N, m) table into host RAM / disk, keep the hot shards
in a device-resident cache behind an indirection table, and serve lookups
through `interp_impl="tiered"` (see repro.core.lram).  Shards can be held
quantized (int8/fp8 payload + per-row scales, `TieredSpec.quant`) on both
tiers, shrinking capacity cost and fill traffic ~4x.  Design narrative in
docs/memstore.md; lookup-path map in docs/architecture.md.

Public surface: `TieredSpec` (static layout config), `TieredValueStore`
(the store), `tiered_interp` (differentiable lookup entry point, also
driving `repro.distributed.sharded_lram.ShardedTieredStore`), and
`find_stores` (locate offloaded stores in a pytree — delegates to the
`repro.core.lookup` store-type registry).  `repro.memstore.interp`
registers the "tiered" placement with the lookup-plan registry.
"""

from repro.memstore.store import (  # noqa: F401
    TieredSpec,
    TieredValueStore,
    find_stores,
)
from repro.memstore.interp import tiered_interp  # noqa: F401
