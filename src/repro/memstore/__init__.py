"""Tiered memory store: host-offloaded value tables + device hot cache.

Capacity past device memory for the LRAM value table (paper: "billions of
entries"): shard the (N, m) table into host RAM / disk, keep the hot shards
in a device-resident cache behind an indirection table, and serve lookups
through `interp_impl="tiered"` (see repro.core.lram).  Design narrative in
docs/memstore.md.
"""

from repro.memstore.store import (  # noqa: F401
    TieredSpec,
    TieredValueStore,
    find_stores,
)
from repro.memstore.interp import tiered_interp  # noqa: F401
