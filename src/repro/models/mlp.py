"""Dense feed-forward blocks: SwiGLU (llama family) and GELU (BERT/GPT2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import nn
from repro.models.config import ModelConfig


def mlp_init(key, cfg: ModelConfig, *, dtype=jnp.float32,
             d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "wi_gate": nn.dense_init(ks[0], d, f, use_bias=cfg.mlp_bias,
                                     dtype=dtype),
            "wi_up": nn.dense_init(ks[1], d, f, use_bias=cfg.mlp_bias,
                                   dtype=dtype),
            "wo": nn.dense_init(ks[2], f, d, use_bias=cfg.mlp_bias,
                                dtype=dtype),
        }
    return {
        "wi": nn.dense_init(ks[0], d, f, use_bias=cfg.mlp_bias, dtype=dtype),
        "wo": nn.dense_init(ks[1], f, d, use_bias=cfg.mlp_bias, dtype=dtype),
    }


def mlp_apply(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.act == "swiglu":
        g = nn.dense(params["wi_gate"], x)
        u = nn.dense(params["wi_up"], x)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = nn.dense(params["wi"], x)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return nn.dense(params["wo"], h)
