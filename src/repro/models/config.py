"""Model configuration covering every assigned architecture family.

One frozen dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM
stacks plus the paper's LRAM & PKM memory-layer insertions, so a single
generic transformer assembly (repro.models.transformer) serves all ten
assigned architectures and the paper's own BERT-style model.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.lram import LRAMConfig
from repro.core.pkm import PKMConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None       # default: d_model // num_heads

    # attention
    attention: str = "full"              # full | swa
    window: int = 4096                   # SWA window
    qkv_bias: bool = False
    rope_theta: float = 1e4
    pos_scheme: str = "rope"             # rope | mrope | learned | none
    mrope_sections: tuple[int, ...] = (16, 24, 24)  # qwen2-vl t/h/w split
    attn_chunk: int = 2048               # kv/q chunking threshold (flash-style)
    attn_impl: str = "auto"              # auto | dense | chunked

    # blocks
    norm: str = "rms"                    # rms | layer
    act: str = "swiglu"                  # swiglu | gelu
    mlp_bias: bool = False
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    top_k_experts: int = 2
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 64
    ssm_conv: int = 4
    hybrid_pattern: int = 0              # zamba2: shared attn every N mamba blocks
    shared_attention: bool = False

    # enc-dec (whisper): frontend is a STUB — input_specs feeds embeddings
    encoder_layers: int = 0
    encoder_len: int = 1500

    # vlm (qwen2-vl): vision frontend is a STUB — input_specs feeds embeddings
    vision_tokens: int = 0

    # memory layers (the paper's technique, first-class)
    lram_layers: tuple[int, ...] = ()
    lram: Optional[LRAMConfig] = None
    pkm_layers: tuple[int, ...] = ()
    pkm: Optional[PKMConfig] = None

    # objective / numerics
    objective: str = "clm"               # clm | mlm
    max_seq: int = 8192                  # for learned positions only
    dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(1, self.num_kv_heads) == 0
        if self.lram_layers:
            assert self.lram is not None
        if self.pkm_layers:
            assert self.pkm is not None

    # ---- derived -----------------------------------------------------------

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND roofline."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        attn = (
            self.num_heads * hd * d
            + 2 * self.num_kv_heads * hd * d
            + self.num_heads * hd * d
        )
        if self.act == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        n = 0
        for i in range(self.num_layers):
            if self.family == "ssm":
                n += self._mamba_params()
                continue
            if self.family == "hybrid":
                n += self._mamba_params()
                continue
            n += attn
            if i in self.lram_layers and self.lram is not None:
                n += self.lram.num_params + d * d + 4 * d * d
            elif i in self.pkm_layers and self.pkm is not None:
                n += self.pkm.num_params
            elif self.num_experts > 0:
                n += self.num_experts * mlp + d * self.num_experts
            else:
                n += mlp
        if self.family == "hybrid" and self.hybrid_pattern:
            n += attn + mlp  # one shared block
        if self.family == "encdec":
            n += self.encoder_layers * (attn + mlp) + self.num_layers * attn
        n += v * d * (1 if self.tie_embeddings else 2)
        return n

    def _mamba_params(self) -> int:
        d, di = self.d_model, self.d_inner
        n_bc = 2 * self.ssm_groups * self.ssm_state
        return (
            d * (2 * di + n_bc + self.ssm_heads)  # in_proj (z,x,B,C,dt)
            + self.ssm_conv * (di + n_bc)         # conv1d
            + 3 * self.ssm_heads                  # A, D, dt_bias
            + di                                  # gate norm
            + di * d                              # out_proj
        )

    def active_param_count(self) -> int:
        """Active params per token (MoE counts only top-k experts)."""
        if self.num_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp = (3 if self.act == "swiglu" else 2) * d * f
        inactive = (self.num_experts - self.top_k_experts) * mlp
        return self.param_count() - self.num_layers * inactive


def validate_cell(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    """Return a skip-reason if (arch x shape) is not runnable, else None."""
    if shape_name.startswith("long"):
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid") or cfg.attention == "swa"
        )
        if not sub_quadratic:
            return (
                "long_500k needs sub-quadratic attention; "
                f"{cfg.name} is pure full-attention (see DESIGN.md §5)"
            )
    return None
