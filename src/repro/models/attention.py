"""Attention: GQA, RoPE / M-RoPE, sliding window, flash-style chunking.

Three execution paths, all numerically identical (tested against each other):

  * dense      — materialises (S, T) scores; short sequences.
  * chunked    — two-level blocking with streaming softmax (running max /
                 denominator carried across KV chunks, scanned over Q chunks).
                 This is the memory-roofline path for 32k prefill: peak
                 activation is O(chunk^2) instead of O(S^2).
  * decode     — single-query step against a (possibly ring-buffered) cache.

All softmax math in float32 regardless of activation dtype.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.distributed import context
from repro.models.config import ModelConfig

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    sections: tuple[int, ...],
) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): positions (3, B, S) for (t, h, w); the
    frequency bands are partitioned across the three position streams."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.asarray(rope_frequencies(d, theta))  # (half,)
    assert sum(sections) == half, (sections, half)
    # build per-band position selection
    band = np.zeros((half,), dtype=np.int32)
    start = 0
    for i, s in enumerate(sections):
        band[start : start + s] = i
        start += s
    band = jnp.asarray(band)
    # angles: select positions[band[j]] for frequency j
    pos = positions.astype(jnp.float32)  # (3, B, S)
    onehot = jax.nn.one_hot(band, 3, dtype=jnp.float32)  # (half, 3)
    sel = jnp.einsum("hc,cbs->bsh", onehot, pos)  # (B, S, half)
    angles = sel * freqs  # (B, S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Core attention math (GQA-aware)
# ---------------------------------------------------------------------------

def _scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, S, Kh, G, D), k: (B, T, Kh, D) -> (B, Kh, G, S, T) in f32."""
    return jnp.einsum(
        "bskgd,btkd->bkgst",
        q.astype(jnp.float32),
        k.astype(jnp.float32),
    )


def _attend(w: jax.Array, v: jax.Array) -> jax.Array:
    """w: (B, Kh, G, S, T), v: (B, T, Kh, D) -> (B, S, Kh, G, D)."""
    return jnp.einsum("bkgst,btkd->bskgd", w, v.astype(w.dtype))


def _band_mask(
    s: int, t: int, *, causal: bool, window: Optional[int], q_offset: int = 0
) -> np.ndarray:
    """(S, T) boolean validity mask. Query i sits at absolute t-position
    q_offset + i."""
    qi = np.arange(s)[:, None] + q_offset
    kj = np.arange(t)[None, :]
    ok = np.ones((s, t), dtype=bool)
    if causal:
        ok &= kj <= qi
    if window is not None:
        ok &= kj > qi - window
    return ok


def dense_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    """q: (B,S,H,D), k/v: (B,T,Kh,D) -> (B,S,H,D)."""
    b, s, h, d = q.shape
    kh = k.shape[2]
    qg = q.reshape(b, s, kh, h // kh, d) * (d**-0.5)
    scores = _scores(qg, k)  # (B,Kh,G,S,T)
    mask = _band_mask(s, k.shape[1], causal=causal, window=window,
                      q_offset=q_offset)
    scores = jnp.where(jnp.asarray(mask), scores, _NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = _attend(w, v)
    return out.reshape(b, s, h, d).astype(q.dtype)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int] = None,
    q_chunk: int = 2048,
    kv_chunk: int = 2048,
) -> jax.Array:
    """Flash-style streaming-softmax attention; O(chunk^2) peak memory.

    Scan over query chunks; inside, scan over KV chunks carrying
    (running_max, denominator, weighted accumulator).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    kh = k.shape[2]
    assert s % q_chunk == 0 and t % kv_chunk == 0, (s, t, q_chunk, kv_chunk)
    nq, nk = s // q_chunk, t // kv_chunk
    qg = (q.reshape(b, s, kh, h // kh, d) * (d**-0.5))
    qg = qg.reshape(b, nq, q_chunk, kh, h // kh, d)
    kc = k.reshape(b, nk, kv_chunk, kh, d)
    vc = v.reshape(b, nk, kv_chunk, kh, d)

    def q_step(_, qi_blk):
        qi, q_blk = qi_blk  # q_blk: (B, q_chunk, Kh, G, D)

        def kv_step(carry, kj_blk):
            m, l, acc = carry
            kj, k_blk, v_blk = kj_blk
            scores = jnp.einsum(
                "bskgd,btkd->bkgst",
                q_blk.astype(jnp.float32),
                k_blk.astype(jnp.float32),
            )  # (B,Kh,G,qc,kc)
            # block-relative band mask
            q_pos = qi * q_chunk + jax.lax.broadcasted_iota(
                jnp.int32, scores.shape, 3
            )
            k_pos = kj * kv_chunk + jax.lax.broadcasted_iota(
                jnp.int32, scores.shape, 4
            )
            ok = jnp.ones(scores.shape, bool)
            if causal:
                ok &= k_pos <= q_pos
            if window is not None:
                ok &= k_pos > q_pos - window
            scores = jnp.where(ok, scores, _NEG_INF)
            m_new = jnp.maximum(m, scores.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(scores - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p, v_blk.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, h // kh, q_chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, h // kh, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kh, h // kh, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out  # (B,Kh,G,qc,D)

    _, outs = jax.lax.scan(
        q_step, None, (jnp.arange(nq), jnp.moveaxis(qg, 1, 0))
    )
    # outs: (nq, B, Kh, G, qc, D) -> (B, S, H, D)
    out = jnp.moveaxis(outs, 0, 1)  # (B, nq, Kh, G, qc, D)
    out = jnp.transpose(out, (0, 1, 4, 2, 3, 5)).reshape(b, s, h, d)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    ring: bool = False,
) -> jax.Array:
    """Single-token decode. q: (B,1,H,D); caches (B,T,Kh,D).

    `cache_len` — number of valid entries (B,) or scalar. With `ring=True`
    the cache is a circular buffer (SWA): all T slots are valid once full,
    and positions are unordered (softmax is permutation-invariant).
    """
    b, _, h, d = q.shape
    t, kh = k_cache.shape[1], k_cache.shape[2]
    qg = q.reshape(b, 1, kh, h // kh, d) * (d**-0.5)
    scores = _scores(qg, k_cache)  # (B,Kh,G,1,T)
    pos = jnp.arange(t)
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    scores = jnp.where(valid[:, None, None, None, :], scores, _NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    # decode reads the whole cache once: keep the attend in the cache dtype
    # (softmax weights <= 1; f32 here would stream 2x the bytes) and pin the
    # weights replicated so the einsum reuses the cache's resident layout.
    B = context.batch_axes()
    mesh = context.get_mesh()
    kh_div = mesh is None or kh % mesh.shape["model"] == 0
    w = context.constrain(w.astype(v_cache.dtype), B, None, None, None, None)
    out = _attend(w, v_cache)  # 'bskgd'
    if kh_div:
        out = context.constrain(out, B, None, "model", None, None)
    else:
        out = context.constrain(out, B, None, None, None, "model")
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention layer (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, *, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, khd, d = cfg.num_heads, cfg.num_kv_heads, cfg.d_model
    hd = cfg.head_dim
    return {
        "wq": nn.dense_init(kq, d, h * hd, use_bias=cfg.qkv_bias, dtype=dtype),
        "wk": nn.dense_init(kk, d, khd * hd, use_bias=cfg.qkv_bias, dtype=dtype),
        "wv": nn.dense_init(kv, d, khd * hd, use_bias=cfg.qkv_bias, dtype=dtype),
        "wo": nn.dense_init(ko, h * hd, d, use_bias=False, dtype=dtype),
    }


def _project_qkv(params, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    q = nn.dense(params["wq"], x).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = nn.dense(params["wk"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = nn.dense(params["wv"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.pos_scheme == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos_scheme == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def attn_apply(
    params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    cross_kv: Optional[tuple[jax.Array, jax.Array]] = None,
):
    """Full-sequence attention (train / prefill compute). x: (B, S, d)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, positions)
    if cross_kv is not None:
        k, v = cross_kv
    window = cfg.window if cfg.attention == "swa" else None
    use_chunked = cfg.attn_impl == "chunked" or (
        cfg.attn_impl == "auto" and s > cfg.attn_chunk and cross_kv is None
    )
    if use_chunked and s % cfg.attn_chunk == 0:
        out = chunked_attention(
            q, k, v, causal=causal, window=window,
            q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk,
        )
    else:
        out = dense_attention(q, k, v, causal=causal, window=window)
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
    y = nn.dense(params["wo"], out)
    return y, (k, v)


def attn_decode(
    params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    pos: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cross: bool = False,
):
    """One decode step. x: (B, 1, d); caches (B, T, Kh, D).

    `pos` is the absolute token position: a scalar (whole batch in lockstep,
    the classic fixed-batch serve loop) or an int32 vector (B,) with one
    position per batch slot (continuous batching: every slot sits at its own
    depth in its own sequence).

    Returns (y, new_k_cache, new_v_cache). For SWA the cache is a ring
    buffer of size `cfg.window`."""
    b = x.shape[0]
    t = k_cache.shape[1]
    per_slot = jnp.ndim(pos) == 1
    if cfg.pos_scheme == "mrope":
        positions = (jnp.broadcast_to(pos[None, :, None], (3, b, 1))
                     if per_slot else jnp.full((3, b, 1), pos, jnp.int32))
    else:
        positions = (pos[:, None].astype(jnp.int32) if per_slot
                     else jnp.full((b, 1), pos, jnp.int32))
    q, k, v = _project_qkv(params, x, cfg, positions)
    # Pin the decode layout: (batch=data, ..., head_dim=model when kv_heads
    # can't split the axis).  Without this the partitioner "involuntarily
    # fully rematerializes" (all-gathers) the 32k cache on every step —
    # EXPERIMENTS.md §Perf cell 2.
    B = context.batch_axes()
    kh_div = (
        context.get_mesh() is None
        or cfg.num_kv_heads % context.get_mesh().shape["model"] == 0
    )
    kv_spec = (B, None, "model", None) if kh_div else (B, None, None, "model")
    q = context.constrain(q, B, None, None, "model" if not kh_div else None)
    k = context.constrain(k, *kv_spec)
    v = context.constrain(v, *kv_spec)
    if cross:
        # cross-attention: cache is the (static) encoder projection
        out = decode_attention(q, k_cache, v_cache,
                               jnp.full((b,), t, jnp.int32))
        new_k, new_v = k_cache, v_cache
    else:
        ring = cfg.attention == "swa"
        slot = pos % t if ring else pos
        if per_slot:
            # one scatter row per batch element: slot i writes at its own
            # position (the write lands before the attend, so a stale row at
            # the new position can never be read back)
            slot = slot if ring else jnp.minimum(slot, t - 1)
            new_k = k_cache.at[jnp.arange(b), slot].set(k[:, 0])
            new_v = v_cache.at[jnp.arange(b), slot].set(v[:, 0])
        else:
            new_k = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k, slot, axis=1
            )
            new_v = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v, slot, axis=1
            )
        new_k = context.constrain(new_k, *kv_spec)
        new_v = context.constrain(new_v, *kv_spec)
        n_valid = jnp.minimum(pos + 1, t)
        out = decode_attention(
            q, new_k, new_v,
            n_valid.astype(jnp.int32) if per_slot
            else jnp.full((b,), n_valid, jnp.int32),
            ring=ring,
        )
    out = out.reshape(b, 1, -1)
    out = context.constrain(out, B, None, "model")
    y = nn.dense(params["wo"], out)
    return y, new_k, new_v
