"""Model blocks that host the memory layer.

Public surface:

  * `repro.models.config`      — `ModelConfig`: one dataclass describing
    every registered arch (family, dims, objective, `lram`/`lram_layers`
    for memory-augmented FFNs, `remat`, …)
  * `repro.models.transformer` — init/forward/loss_fn, prefill +
    decode_step (KV caches), the host for dense / moe / mamba blocks and
    the LRAM memory FFN
  * `repro.models.attention`   — MHA/GQA attention with cache support
  * `repro.models.mlp`         — dense FFN blocks
  * `repro.models.moe`         — mixture-of-experts FFN
  * `repro.models.mamba2`      — Mamba-2 SSM blocks

Configs select blocks per layer; see `repro.configs` for the registry.
"""
