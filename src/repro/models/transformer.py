"""Generic transformer assembly for every assigned architecture.

The stack is compiled as a sequence of *segments*:

  * ("run", n)       — n identical layers executed under jax.lax.scan with
                       stacked params (compile time O(1) in depth — essential
                       for 80-layer models x 80 dry-run compiles),
  * ("memory", kind) — a single un-scanned layer whose FFN is replaced by the
                       paper's LRAM block (or the PKM baseline). Un-scanned
                       because it carries batchnorm state and its own shapes.
  * hybrid family    — zamba2: units of `hybrid_pattern` mamba blocks + one
                       invocation of a SHARED attention+MLP block, scanned
                       over units with the shared params closed over
                       (parameter sharing across depth, zamba2-style).

Modes: full-sequence (train / prefill, builds KV caches) and single-token
decode (consumes ring/linear caches).  Caches for scanned runs are stacked
along the layer axis and threaded through the scan as xs/ys.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core import lram as lram_mod
from repro.core import pkm as pkm_mod
from repro.models import attention, mamba2, mlp, moe
from repro.models.config import ModelConfig

IGNORE = -100


# ---------------------------------------------------------------------------
# Segment plan
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig) -> list[tuple]:
    """[("run", count) | ("memory", layer_idx, kind)] covering all layers."""
    special = {i: "lram" for i in cfg.lram_layers}
    special.update({i: "pkm" for i in cfg.pkm_layers})
    if cfg.family == "hybrid":
        assert not special, "memory layers inside hybrid units not supported"
        assert cfg.num_layers % cfg.hybrid_pattern == 0
        return [("hybrid", cfg.num_layers // cfg.hybrid_pattern)]
    plan: list[tuple] = []
    run = 0
    for i in range(cfg.num_layers):
        if i in special:
            if run:
                plan.append(("run", run))
                run = 0
            plan.append(("memory", i, special[i]))
        else:
            run += 1
    if run:
        plan.append(("run", run))
    return plan


# ---------------------------------------------------------------------------
# Single-layer blocks
# ---------------------------------------------------------------------------

def _norm_init(cfg, dtype):
    if cfg.norm == "layer":
        return nn.layernorm_init(cfg.d_model, dtype=dtype)
    return nn.rmsnorm_init(cfg.d_model, dtype=dtype)


def _norm(cfg, params, x):
    if cfg.norm == "layer":
        return nn.layernorm(params, x)
    return nn.rmsnorm(params, x)


def _layer_init(key, cfg: ModelConfig, *, dtype, cross: bool = False):
    ks = jax.random.split(key, 6)
    if cfg.family == "ssm":
        return {
            "norm": _norm_init(cfg, dtype),
            "mamba": mamba2.mamba_init(ks[0], cfg, dtype=dtype),
        }
    p = {
        "attn_norm": _norm_init(cfg, dtype),
        "attn": attention.attn_init(ks[0], cfg, dtype=dtype),
        "ffn_norm": _norm_init(cfg, dtype),
    }
    if cross:
        p["cross_norm"] = _norm_init(cfg, dtype)
        p["cross"] = attention.attn_init(ks[1], cfg, dtype=dtype)
    if cfg.num_experts > 0:
        p["moe"] = moe.moe_init(ks[2], cfg, dtype=dtype)
    else:
        p["mlp"] = mlp.mlp_init(ks[2], cfg, dtype=dtype)
    return p


def _layer_full(lp, x, cfg: ModelConfig, positions, *, causal,
                enc_out=None):
    """Full-sequence layer. Returns (x, kv, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        x = x + mamba2.mamba_apply(lp["mamba"], _norm(cfg, lp["norm"], x), cfg)
        return x, None, aux
    h, kv = attention.attn_apply(
        lp["attn"], _norm(cfg, lp["attn_norm"], x), cfg,
        positions=positions, causal=causal,
    )
    x = x + h
    if "cross" in lp:
        ek, ev = enc_out
        h, _ = attention.attn_apply(
            lp["cross"], _norm(cfg, lp["cross_norm"], x), cfg,
            positions=positions, causal=False, cross_kv=(ek, ev),
        )
        x = x + h
    y = _norm(cfg, lp["ffn_norm"], x)
    if cfg.num_experts > 0:
        y, aux = moe.moe_apply(lp["moe"], y, cfg)
    else:
        y = mlp.mlp_apply(lp["mlp"], y, cfg)
    return x + y, kv, aux


def _layer_decode(lp, x, cfg: ModelConfig, pos, cache, *, enc_out=None):
    """Single-token decode. Returns (x, new_cache)."""
    if cfg.family == "ssm":
        h, new_cache = mamba2.mamba_decode(
            lp["mamba"], _norm(cfg, lp["norm"], x), cfg, cache
        )
        return x + h, new_cache
    h, nk, nv = attention.attn_decode(
        lp["attn"], _norm(cfg, lp["attn_norm"], x), cfg,
        pos=pos, k_cache=cache["k"], v_cache=cache["v"],
    )
    x = x + h
    new_cache = dict(cache, k=nk, v=nv)
    if "cross" in lp:
        h, _, _ = attention.attn_decode(
            lp["cross"], _norm(cfg, lp["cross_norm"], x), cfg,
            pos=pos, k_cache=cache["ck"], v_cache=cache["cv"], cross=True,
        )
        x = x + h
    y = _norm(cfg, lp["ffn_norm"], x)
    if cfg.num_experts > 0:
        y, _ = moe.moe_apply(lp["moe"], y, cfg)
    else:
        y = mlp.mlp_apply(lp["mlp"], y, cfg)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# Memory (LRAM / PKM) layers: attention + memory-FFN
# ---------------------------------------------------------------------------

def _memory_layer_init(key, cfg: ModelConfig, kind: str, *, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "attn_norm": _norm_init(cfg, dtype),
        "attn": attention.attn_init(ks[0], cfg, dtype=dtype),
        "ffn_norm": _norm_init(cfg, dtype),
    }
    state: dict[str, Any] = {}
    if kind == "lram":
        p["memffn"], state = lram_mod.memffn_init(
            ks[1], cfg.d_model, cfg.lram, dtype=dtype
        )
    else:
        p["pkm"], state = pkm_mod.pkm_init(ks[1], cfg.d_model, cfg.pkm,
                                           dtype=dtype)
    return p, state


def _memory_layer_full(lp, st, x, cfg, positions, kind, *, causal, train,
                       collect_access: bool = False):
    access = None
    if cfg.family != "ssm":
        h, kv = attention.attn_apply(
            lp["attn"], _norm(cfg, lp["attn_norm"], x), cfg,
            positions=positions, causal=causal,
        )
        x = x + h
    else:
        # attention-free host: LRAM block inserted directly on the residual
        # stream (paper §6: sparse memory for recurrent architectures)
        kv = None
    y = _norm(cfg, lp["ffn_norm"], x)
    if kind == "lram":
        if collect_access:
            q = nn.dense(lp["memffn"]["wi"], y)
            hh, new_st, access = lram_mod.lram_apply(
                lp["memffn"]["lram"], st["lram"], q, cfg.lram, train=train,
                return_access=True,
            )
            h = nn.dense(lp["memffn"]["wo"], hh)
            new_st = {"lram": new_st}
        else:
            h, new_st = lram_mod.memffn_apply(
                lp["memffn"], st, y, cfg.lram, train=train
            )
    else:
        if collect_access:
            h, new_st, access = pkm_mod.pkm_apply(
                lp["pkm"], st, y, cfg.pkm, train=train, return_access=True
            )
        else:
            h, new_st = pkm_mod.pkm_apply(lp["pkm"], st, y, cfg.pkm,
                                          train=train)
    return x + h, kv, new_st, access


def _memory_layer_decode(lp, st, x, cfg, pos, cache, kind):
    if cfg.family == "ssm":
        y = _norm(cfg, lp["ffn_norm"], x)
        if kind == "lram":
            h, _ = lram_mod.memffn_apply(lp["memffn"], st, y, cfg.lram)
        else:
            h, _ = pkm_mod.pkm_apply(lp["pkm"], st, y, cfg.pkm)
        return x + h, cache
    h, nk, nv = attention.attn_decode(
        lp["attn"], _norm(cfg, lp["attn_norm"], x), cfg,
        pos=pos, k_cache=cache["k"], v_cache=cache["v"],
    )
    x = x + h
    y = _norm(cfg, lp["ffn_norm"], x)
    if kind == "lram":
        h, _ = lram_mod.memffn_apply(lp["memffn"], st, y, cfg.lram)
    else:
        h, _ = pkm_mod.pkm_apply(lp["pkm"], st, y, cfg.pkm)
    return x + h, dict(cache, k=nk, v=nv)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _stack_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init(key, cfg: ModelConfig):
    """Returns (params, state)."""
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 16)
    params: dict[str, Any] = {
        "embed": nn.embedding_init(keys[0], cfg.vocab_size, cfg.d_model,
                                   dtype=dtype),
        "final_norm": _norm_init(cfg, dtype),
    }
    state: dict[str, Any] = {}
    if cfg.pos_scheme == "learned":
        params["pos_embed"] = nn.truncated_normal_init(0.02)(
            keys[1], (cfg.max_seq, cfg.d_model), dtype
        )
    if not cfg.tie_embeddings:
        params["lm_head"] = nn.dense_init(
            keys[2], cfg.d_model, cfg.vocab_size, use_bias=False, dtype=dtype
        )

    if cfg.family == "encdec":
        params["enc_pos_embed"] = nn.truncated_normal_init(0.02)(
            keys[3], (cfg.encoder_len, cfg.d_model), dtype
        )
        enc_cfg = dataclasses.replace(cfg, num_experts=0)
        params["encoder"] = _stack_init(
            lambda k: _layer_init(k, enc_cfg, dtype=dtype),
            keys[4], cfg.encoder_layers,
        )
        params["enc_norm"] = _norm_init(cfg, dtype)

    segs: dict[str, Any] = {}
    for si, seg in enumerate(layer_plan(cfg)):
        kseg = jax.random.fold_in(keys[5], si)
        if seg[0] == "run":
            cross = cfg.family == "encdec"
            segs[f"seg{si}"] = _stack_init(
                lambda k: _layer_init(k, cfg, dtype=dtype, cross=cross),
                kseg, seg[1],
            )
        elif seg[0] == "hybrid":
            ssm_cfg = dataclasses.replace(cfg, family="ssm")
            unit_init = lambda k: _stack_init(
                lambda kk: _layer_init(kk, ssm_cfg, dtype=dtype),
                k, cfg.hybrid_pattern,
            )
            segs[f"seg{si}"] = _stack_init(unit_init, kseg, seg[1])
            dense_cfg = dataclasses.replace(cfg, family="dense")
            params["shared_attn"] = _layer_init(
                keys[6], dense_cfg, dtype=dtype
            )
        else:
            _, idx, kind = seg
            segs[f"seg{si}"], st = _memory_layer_init(kseg, cfg, kind,
                                                      dtype=dtype)
            state[f"seg{si}"] = st
    params["segments"] = segs
    return params, state


# ---------------------------------------------------------------------------
# Forward (full sequence)
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"]["embedding"], tokens, axis=0)
    if cfg.vision_tokens and "vision_embeds" in batch:
        vt = cfg.vision_tokens
        x = jnp.concatenate(
            [batch["vision_embeds"].astype(x.dtype), x[:, vt:]], axis=1
        )
    if cfg.pos_scheme == "learned":
        x = x + params["pos_embed"][:s][None].astype(x.dtype)
    if cfg.pos_scheme == "mrope":
        positions = batch.get(
            "positions",
            jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (3, b, s)),
        )
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )


def _scan_layers(body, x, stacked, cfg: ModelConfig):
    """lax.scan over stacked layer params, or an unrolled python loop.

    Unrolled mode exists for the dry-run: XLA's cost_analysis counts a
    while-loop body ONCE regardless of trip count, so roofline FLOP/byte
    accounting requires the unrolled graph.  Both modes share params layout.
    """
    if cfg.scan_layers:
        return jax.lax.scan(body, x, stacked)
    n = jax.tree.leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        x, y = body(x, jax.tree.map(lambda a: a[i], stacked))
        ys.append(y)
    if ys and ys[0] is not None:
        stacked_ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        stacked_ys = None
    return x, stacked_ys


def _run_encoder(params, batch, cfg: ModelConfig):
    x = batch["encoder_embeds"].astype(jnp.dtype(cfg.dtype))
    s = x.shape[1]
    x = x + params["enc_pos_embed"][:s][None].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                 (x.shape[0], s))
    enc_cfg = dataclasses.replace(cfg, num_experts=0, attn_impl="dense")

    def body(x, lp):
        y, _, _ = _layer_full(lp, x, enc_cfg, positions, causal=False)
        return y, None

    x, _ = _scan_layers(_maybe_remat(body, cfg), x, params["encoder"], cfg)
    return _norm(cfg, params["enc_norm"], x)


def forward(params, state, batch, cfg: ModelConfig, *, train: bool = False,
            collect_access: bool = False):
    """Full-sequence forward. Returns (logits, new_state, aux_loss)
    [+ memory-access dict {seg: (idx, w)} when collect_access=True]."""
    causal = cfg.objective == "clm"
    accesses: dict[str, Any] = {}
    x, positions = _embed_inputs(params, batch, cfg)
    enc_kv = None
    if cfg.family == "encdec":
        enc_x = _run_encoder(params, batch, cfg)
        enc_kv = enc_x  # projected per layer below

    new_state: dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)
    for si, seg in enumerate(layer_plan(cfg)):
        name = f"seg{si}"
        sp = params["segments"][name]
        if seg[0] == "run":
            def body(x, lp):
                enc = None
                if enc_kv is not None:
                    b, t = enc_kv.shape[:2]
                    ek = nn.dense(lp["cross"]["wk"], enc_kv).reshape(
                        b, t, cfg.num_kv_heads, cfg.head_dim
                    )
                    ev = nn.dense(lp["cross"]["wv"], enc_kv).reshape(
                        b, t, cfg.num_kv_heads, cfg.head_dim
                    )
                    enc = (ek, ev)
                y, _, aux = _layer_full(lp, x, cfg, positions,
                                        causal=causal, enc_out=enc)
                return y, aux

            x, auxs = _scan_layers(_maybe_remat(body, cfg), x, sp, cfg)
            aux_total = aux_total + auxs.sum()
        elif seg[0] == "hybrid":
            shared = params["shared_attn"]
            ssm_cfg = dataclasses.replace(cfg, family="ssm")
            dense_cfg = dataclasses.replace(cfg, family="dense")

            def unit(x, up):
                def mbody(x, lp):
                    y, _, _ = _layer_full(lp, x, ssm_cfg, positions,
                                          causal=True)
                    return y, None
                x, _ = _scan_layers(mbody, x, up, cfg)
                y, _, _ = _layer_full(shared, x, dense_cfg, positions,
                                      causal=True)
                return y, None

            x, _ = _scan_layers(_maybe_remat(unit, cfg), x, sp, cfg)
        else:
            _, idx, kind = seg
            x, _, st, access = _memory_layer_full(
                sp, state[name], x, cfg, positions, kind,
                causal=causal, train=train, collect_access=collect_access,
            )
            new_state[name] = st
            if access is not None:
                accesses[name] = access

    x = _norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["embedding"].astype(x.dtype).T
    else:
        logits = nn.dense(params["lm_head"], x)
    if collect_access:
        return logits, new_state or state, aux_total, accesses
    return logits, new_state or state, aux_total


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def loss_fn(params, state, batch, cfg: ModelConfig, *, train: bool = True,
            collect_access: bool = False):
    """Scalar loss + aux.  `collect_access=True` additionally returns the
    memory-access dict {seg: (idx, w)} from the forward pass (the
    telemetry train step scatter-adds `idx` into its usage counters)."""
    if collect_access:
        logits, new_state, aux, accesses = forward(
            params, state, batch, cfg, train=train, collect_access=True
        )
    else:
        logits, new_state, aux = forward(
            params, state, batch, cfg, train=train
        )
        accesses = None
    labels = batch["labels"]
    valid = labels != IGNORE
    safe_labels = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok_ll = jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(valid.sum(), 1)
    xent = -(tok_ll * valid).sum() / denom
    loss = xent + cfg.router_aux_weight * aux
    metrics = {"xent": xent, "aux": aux, "ntokens": denom}
    if collect_access:
        return loss, (new_state, metrics, accesses)
    return loss, (new_state, metrics)


# ---------------------------------------------------------------------------
# KV-cache serving: cache construction, prefill, decode
# ---------------------------------------------------------------------------

def _attn_cache_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.attention == "swa":
        return min(cfg.window, max_len)
    return max_len


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int):
    """Nested dict of (shape, dtype) — basis for zeros-init AND dry-run
    ShapeDtypeStructs (no allocation)."""
    dtype = jnp.dtype(cfg.dtype)
    t = _attn_cache_len(cfg, max_len)
    kvd = (batch, t, cfg.num_kv_heads, cfg.head_dim)
    shapes: dict[str, Any] = {}
    for si, seg in enumerate(layer_plan(cfg)):
        name = f"seg{si}"
        if seg[0] == "run":
            n = seg[1]
            if cfg.family == "ssm":
                ms = mamba2.mamba_cache_shapes(cfg, batch)
                shapes[name] = {
                    "ssm": ((n,) + ms["ssm"], jnp.float32),
                    "conv": ((n,) + ms["conv"], jnp.float32),
                }
            else:
                shapes[name] = {
                    "k": ((n,) + kvd, dtype),
                    "v": ((n,) + kvd, dtype),
                }
                if cfg.family == "encdec":
                    ckv = (batch, cfg.encoder_len, cfg.num_kv_heads,
                           cfg.head_dim)
                    shapes[name]["ck"] = ((n,) + ckv, dtype)
                    shapes[name]["cv"] = ((n,) + ckv, dtype)
        elif seg[0] == "hybrid":
            units = seg[1]
            ms = mamba2.mamba_cache_shapes(cfg, batch)
            shapes[name] = {
                "ssm": ((units, cfg.hybrid_pattern) + ms["ssm"], jnp.float32),
                "conv": ((units, cfg.hybrid_pattern) + ms["conv"],
                         jnp.float32),
                "k": ((units,) + kvd, dtype),
                "v": ((units,) + kvd, dtype),
            }
        else:
            if cfg.family == "ssm":
                shapes[name] = {}
            else:
                shapes[name] = {"k": (kvd, dtype), "v": (kvd, dtype)}
    return shapes


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(
        lambda sd: jnp.zeros(sd[0], sd[1]),
        cache_shapes(cfg, batch, max_len),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple),
    )


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct(sd[0], sd[1]),
        cache_shapes(cfg, batch, max_len),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple),
    )


def decode_step(params, state, tokens, pos, cache, cfg: ModelConfig,
                batch_extras: Optional[dict] = None):
    """One serving step: tokens (B, 1) at absolute position `pos`.

    `pos` is a scalar (the classic lockstep batch) or an int32 vector (B,)
    carrying one absolute position per batch slot — the continuous-batching
    engine (repro.serving) drives every decode through the vector form, so
    sequences at different depths share one fixed-shape compiled step.

    Returns (logits (B, 1, V), new_cache).  This is the function the
    `decode_*` / `long_*` dry-run cells lower.
    """
    b = tokens.shape[0]
    x = jnp.take(params["embed"]["embedding"], tokens, axis=0)
    if cfg.pos_scheme == "learned":
        if jnp.ndim(pos) == 1:
            x = x + jnp.take(
                params["pos_embed"],
                jnp.minimum(pos, params["pos_embed"].shape[0] - 1), axis=0,
            )[:, None].astype(x.dtype)
        else:
            x = x + jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], pos, 1, axis=0
            )[None].astype(x.dtype)

    new_cache: dict[str, Any] = {}
    for si, seg in enumerate(layer_plan(cfg)):
        name = f"seg{si}"
        sp = params["segments"][name]
        c = cache[name]
        if seg[0] == "run":
            def body(x, lp_c):
                lp, ci = lp_c
                y, co = _layer_decode(lp, x, cfg, pos, ci)
                return y, co

            x, co = _scan_layers(body, x, (sp, c), cfg)
            new_cache[name] = co
        elif seg[0] == "hybrid":
            shared = params["shared_attn"]
            ssm_cfg = dataclasses.replace(cfg, family="ssm")
            dense_cfg = dataclasses.replace(cfg, family="dense")

            def unit(x, up_c):
                up, ci = up_c

                def mbody(x, lp_mc):
                    lp, mc = lp_mc
                    y, co = _layer_decode(lp, x, ssm_cfg, pos, mc)
                    return y, co

                x, mco = _scan_layers(
                    mbody, x, (up, {"ssm": ci["ssm"], "conv": ci["conv"]}),
                    cfg,
                )
                y, aco = _layer_decode(
                    shared, x, dense_cfg, pos, {"k": ci["k"], "v": ci["v"]}
                )
                return y, {**mco, **aco}

            x, co = _scan_layers(unit, x, (sp, c), cfg)
            new_cache[name] = co
        else:
            _, idx, kind = seg
            x, co = _memory_layer_decode(sp, state[name], x, cfg, pos, c,
                                         kind)
            new_cache[name] = co

    x = _norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["embedding"].astype(x.dtype).T
    else:
        logits = nn.dense(params["lm_head"], x)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Slotted KV-cache manager (continuous batching)
# ---------------------------------------------------------------------------
#
# The decode cache built by `init_cache(cfg, B, max_len)` is *slotted*: the
# batch axis is a pool of B fixed-shape slots, each holding one in-flight
# sequence.  The continuous-batching engine (repro.serving) admits a new
# request by prefilling it at batch=1 and splicing the resulting sub-cache
# into a free slot, and retires a finished one by simply marking the slot
# free — the next admission overwrites every cache position, so no explicit
# clearing is needed.  Because segment kinds stack their caches differently
# (scanned runs carry a leading layer axis, hybrid units two), the batch
# axis is *derived* per leaf rather than assumed.

def cache_batch_axes(cfg: ModelConfig, max_len: int):
    """Pytree matching the cache with each leaf's batch-axis index.

    Derived by diffing `cache_shapes` at two batch sizes — robust to any
    segment layout (plain runs, hybrid units, memory layers) without
    hard-coding per-family axis positions."""
    one = cache_shapes(cfg, 1, max_len)
    two = cache_shapes(cfg, 2, max_len)
    is_leaf = lambda x: isinstance(x, tuple) and isinstance(x[0], tuple)

    def axis(a, b):
        for i, (da, db) in enumerate(zip(a[0], b[0])):
            if da != db:
                return i
        raise ValueError(f"cache leaf {a[0]} has no batch axis")

    return jax.tree.map(axis, one, two, is_leaf=is_leaf)


def write_cache_slot(cache, sub_cache, slot, axes):
    """Splice a batch=1 `sub_cache` (from a single-request prefill) into
    batch slot `slot` of a slotted cache.  `axes` comes from
    `cache_batch_axes`; `slot` may be traced (the engine jits this with the
    big cache donated, so admission never copies the pool)."""
    def upd(c, s, ax):
        return jax.lax.dynamic_update_slice_in_dim(
            c, s.astype(c.dtype), slot, axis=ax
        )

    return jax.tree.map(upd, cache, sub_cache, axes)


def _fill_kv_cache(k_new, v_new, cfg: ModelConfig, t_cache: int, s: int):
    """Map prefill K/V (.., s, Kh, D) onto the decode cache layout.

    Full attention: slot = position (pad tail).  SWA ring buffer:
    slot = position % window — the last `window` positions hit each slot
    exactly once, so the fill is the argsort permutation of their slots.
    Works for arrays with any number of leading dims before the seq axis -2
    ... here seq axis is -3 (…, s, Kh, D)."""
    if cfg.attention == "swa" and s > t_cache:
        keep = np.arange(s - t_cache, s)
        order = np.argsort(keep % t_cache)
        k_new = jnp.take(k_new, jnp.asarray(keep[order]), axis=-3)
        v_new = jnp.take(v_new, jnp.asarray(keep[order]), axis=-3)
    pad = t_cache - k_new.shape[-3]
    if pad > 0:
        widths = [(0, 0)] * k_new.ndim
        widths[-3] = (0, pad)
        k_new = jnp.pad(k_new, widths)
        v_new = jnp.pad(v_new, widths)
    return k_new, v_new


def _mamba_prefill_body(lp, x, cfg: ModelConfig, s: int):
    """Mamba layer full forward that also emits (final_state, conv_tail)."""
    u = _norm(cfg, lp["norm"], x)
    z, xbc_raw, dt_raw = mamba2._split_proj(lp["mamba"], u, cfg)
    xbc = mamba2._causal_conv(xbc_raw, lp["mamba"]["conv"])
    xx, B, C, dt = mamba2._post_conv(xbc, dt_raw, lp["mamba"], cfg)
    A = -jnp.exp(lp["mamba"]["A_log"])
    if s % cfg.ssm_chunk == 0 and s > 1:
        y, hf = mamba2.ssd_chunked(xx, B, C, dt, A, chunk=cfg.ssm_chunk)
    else:
        y, hf = mamba2.ssd_sequential(xx, B, C, dt, A)
    y = y + lp["mamba"]["D"][:, None] * xx.astype(jnp.float32)
    y = y.reshape(*u.shape[:-1], cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = nn.rmsnorm(lp["mamba"]["norm"], y)
    y = nn.dense(lp["mamba"]["out_proj"], y.astype(u.dtype))
    nconv = cfg.ssm_conv - 1
    if s >= nconv:
        conv_tail = xbc_raw[:, s - nconv:, :]
    else:
        conv_tail = jnp.pad(xbc_raw, ((0, 0), (nconv - s, 0), (0, 0)))
    return x + y, hf, conv_tail.astype(jnp.float32)


def prefill(params, state, batch, cfg: ModelConfig, max_len: int):
    """Run the full prompt, building decode caches. Returns (logits, cache).

    Supports every family; the `prefill_*` dry-run cells lower this."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len)
    x, positions = _embed_inputs(params, batch, cfg)
    enc_kv = None
    if cfg.family == "encdec":
        enc_kv = _run_encoder(params, batch, cfg)

    def _enc_proj(lp):
        if enc_kv is None:
            return None
        bb, t = enc_kv.shape[:2]
        ek = nn.dense(lp["cross"]["wk"], enc_kv).reshape(
            bb, t, cfg.num_kv_heads, cfg.head_dim)
        ev = nn.dense(lp["cross"]["wv"], enc_kv).reshape(
            bb, t, cfg.num_kv_heads, cfg.head_dim)
        return ek, ev

    for si, seg in enumerate(layer_plan(cfg)):
        name = f"seg{si}"
        sp = params["segments"][name]
        t_attn = _attn_cache_len(cfg, max_len)
        if seg[0] == "run":
            if cfg.family == "ssm":
                def body(x, lp):
                    y, hf, convt = _mamba_prefill_body(lp, x, cfg, s)
                    return y, (hf, convt)

                x, (hf, convt) = _scan_layers(body, x, sp, cfg)
                cache[name] = {"ssm": hf, "conv": convt}
            else:
                def body(x, lp):
                    enc = _enc_proj(lp)
                    y, kv, _ = _layer_full(lp, x, cfg, positions,
                                           causal=True, enc_out=enc)
                    out = (kv[0], kv[1]) + ((enc[0], enc[1]) if enc else ())
                    return y, out

                x, kvs = _scan_layers(body, x, sp, cfg)
                k_new, v_new = _fill_kv_cache(kvs[0], kvs[1], cfg, t_attn, s)
                cache[name]["k"] = k_new
                cache[name]["v"] = v_new
                if cfg.family == "encdec":
                    cache[name]["ck"] = kvs[2]
                    cache[name]["cv"] = kvs[3]
        elif seg[0] == "hybrid":
            shared = params["shared_attn"]
            ssm_cfg = dataclasses.replace(cfg, family="ssm")
            dense_cfg = dataclasses.replace(cfg, family="dense")

            def unit(x, up):
                def mbody(x, lp):
                    y, hf, convt = _mamba_prefill_body(lp, x, ssm_cfg, s)
                    return y, (hf, convt)

                x, (hf, convt) = _scan_layers(mbody, x, up, cfg)
                y, kv, _ = _layer_full(shared, x, dense_cfg, positions,
                                       causal=True)
                return y, (hf, convt, kv[0], kv[1])

            x, (hf, convt, k_new, v_new) = _scan_layers(unit, x, sp, cfg)
            k_new, v_new = _fill_kv_cache(k_new, v_new, cfg, t_attn, s)
            cache[name] = {"ssm": hf, "conv": convt, "k": k_new, "v": v_new}
        else:
            _, idx, kind = seg
            x, kv, _, _ = _memory_layer_full(
                sp, state[name], x, cfg, positions, kind,
                causal=True, train=False,
            )
            if kv is not None:
                k_new, v_new = _fill_kv_cache(kv[0], kv[1], cfg, t_attn, s)
                cache[name] = {"k": k_new, "v": v_new}

    x = _norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["embedding"].astype(x.dtype).T
    else:
        logits = nn.dense(params["lm_head"], x)
    return logits, cache
