"""Top-k sparse Mixture-of-Experts (Mixtral / Phi-3.5-MoE).

Capacity-based, sort-free dispatch designed for GSPMD sharding:

  1. router logits -> top-k experts + renormalised gate weights per token,
  2. position-in-expert via an exclusive cumulative sum over the one-hot
     assignment matrix (no data-dependent shapes: tokens beyond the expert's
     capacity C are *dropped*, the standard TPU MoE discipline),
  3. scatter-add token copies into an (E, C, d) buffer, batched expert FFN
     as one einsum over stacked expert weights (E is sharded on the `model`
     mesh axis = expert parallelism), gather back and weight by gates.

Also emits the switch-style load-balancing auxiliary loss.  SMoE is the
paper's O(sqrt N) comparison point (§5): LRAM replaces exactly this block
when `lram_layers` covers an MoE layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import nn
from repro.distributed import context
from repro.models.config import ModelConfig


def moe_init(key, cfg: ModelConfig, *, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, kg, ku, ko = jax.random.split(key, 4)
    init = nn.fan_in_init()
    if cfg.act == "swiglu":
        experts = {
            "wi_gate": init(kg, (e, d, f), dtype),
            "wi_up": init(ku, (e, d, f), dtype),
            "wo": init(ko, (e, f, d), dtype),
        }
    else:
        experts = {"wi": init(kg, (e, d, f), dtype),
                   "wo": init(ko, (e, f, d), dtype)}
    return {
        "router": nn.dense_init(kr, d, e, use_bias=False, dtype=dtype),
        "experts": experts,
    }


def _expert_ffn(experts, xb: jax.Array, cfg: ModelConfig) -> jax.Array:
    """xb: (E, C, d) -> (E, C, d), one batched einsum per projection."""
    if cfg.act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xb, experts["wi_gate"].astype(xb.dtype))
        u = jnp.einsum("ecd,edf->ecf", xb, experts["wi_up"].astype(xb.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xb.dtype) * u
    else:
        h = jnp.einsum("ecd,edf->ecf", xb, experts["wi"].astype(xb.dtype))
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(xb.dtype)
    return jnp.einsum("ecf,efd->ecd", h, experts["wo"].astype(h.dtype))


def moe_apply(params, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux_loss).

    GShard-style grouping: each sequence is its own dispatch group with
    capacity C = cf * S * k / E.  All dispatch tensors keep the batch dim
    leading, so under GSPMD the scatter/gather partition cleanly on the
    `data` axis while experts stay on `model` (EP) — nothing global, no
    cross-shard cumsum."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k_experts

    logits = nn.dense(params["router"], x).astype(jnp.float32)  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)              # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # ---- load-balancing auxiliary loss (Switch) ---------------------------
    me = probs.mean(axis=(0, 1))                                 # (E,)
    onehot_top1 = jax.nn.one_hot(expert_ids[..., 0], e, dtype=jnp.float32)
    ce = onehot_top1.mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # ---- per-sequence capacity & position-in-expert -----------------------
    cap = int(max(1, cfg.capacity_factor * s * k / e))
    ids = expert_ids.reshape(b, s * k)                           # (B, S*k)
    gts = gate_vals.reshape(b, s * k)
    onehot = jax.nn.one_hot(ids, e, dtype=jnp.int32)             # (B, S*k, E)
    pos = jnp.cumsum(onehot, axis=1) - 1
    pos_in_e = jnp.take_along_axis(pos, ids[..., None], axis=2)[..., 0]
    keep = (pos_in_e < cap).astype(jnp.float32)                  # (B, S*k)
    slot = jnp.where(pos_in_e < cap, ids * cap + pos_in_e, 0)    # (B, S*k)

    # ---- dispatch / expert compute / combine (batch dim stays leading) ----
    # Every scatter/gather operand is pinned to the batch=data layout so the
    # partitioner recognises dim 0 (iota indices) as a parallel scatter dim
    # and keeps the dispatch local to each data shard.  When E divides the
    # model axis (true expert parallelism) the flattened (E*cap) slot dim
    # additionally rides `model`: the scatter/gather then IS the
    # token<->expert exchange and everything else stays local.
    B = context.batch_axes()
    mesh = context.get_mesh()
    e_div = mesh is None or e % mesh.shape["model"] == 0
    # with true EP (E % model == 0) GSPMD partitions the dispatch well on
    # its own; the constraints below repair only the TP-within-expert path
    c = (lambda x, *_: x) if e_div else context.constrain
    src = jnp.repeat(jnp.arange(s), k)                           # (S*k,)
    xsrc = jnp.take(x, src, axis=1)                              # (B, S*k, d)
    contrib = xsrc * keep[..., None].astype(x.dtype)
    contrib = c(contrib, B, None, None)
    bi = jnp.broadcast_to(jnp.arange(b)[:, None], slot.shape)
    buf = jnp.zeros((b, e * cap, d), x.dtype).at[bi, slot].add(contrib)
    buf = c(buf, B, None, None)
    yb = _expert_ffn_grouped(
        params["experts"], buf.reshape(b, e, cap, d), cfg
    )
    flat = c(yb.reshape(b, e * cap, d), B, None, None)
    gathered = flat[bi, slot]                                    # (B, S*k, d)
    gathered = c(gathered, B, None, None)
    wts = (gts * keep).astype(x.dtype)
    y = jnp.zeros_like(x).at[
        bi, jnp.broadcast_to(src[None], slot.shape)
    ].add(gathered * wts[..., None])
    y = c(y, B, None, None)
    return y, aux


def _expert_ffn_grouped(experts, xb: jax.Array, cfg: ModelConfig):
    """xb: (B, E, C, d) -> (B, E, C, d); E contracts against stacked expert
    weights, B stays on `data`.

    The sharding constraints pin the activation layout to
    (batch=data, expert/hidden=model): without them GSPMD may contract over
    an FSDP-sharded weight dim and all-reduce activation-sized partials
    (42 TiB/step on mixtral — EXPERIMENTS.md §Perf iteration 2)."""
    B = context.batch_axes()
    e_div = context.get_mesh() is None or (
        cfg.num_experts % context.get_mesh().shape["model"] == 0
    )
    if e_div:
        # true expert parallelism: GSPMD already handles the E-sharded
        # einsums well (phi-3.5 path) — constraints only hurt here
        def c(x, *_):
            return x
        spec_h = ()
    else:
        # TP within each expert: pin (batch=data, hidden=model) so GSPMD
        # cannot contract over the FSDP-sharded weight dim
        c = context.constrain
        spec_h = (B, None, None, "model")
        xb = c(xb, B, None, None, None)
    if cfg.act == "swiglu":
        g = jnp.einsum("becd,edf->becf", xb,
                       experts["wi_gate"].astype(xb.dtype))
        u = jnp.einsum("becd,edf->becf", xb,
                       experts["wi_up"].astype(xb.dtype))
        g = c(g, *spec_h)
        u = c(u, *spec_h)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xb.dtype) * u
    else:
        h = jnp.einsum("becd,edf->becf", xb, experts["wi"].astype(xb.dtype))
        h = c(h, *spec_h)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(xb.dtype)
    out = jnp.einsum("becf,efd->becd", h, experts["wo"].astype(h.dtype))
    return c(out, B, None, None, None)


def moe_apply_dense_reference(params, x: jax.Array, cfg: ModelConfig):
    """O(E)-compute oracle: run every expert on every token, mask by gates.
    Used only in tests (no capacity drops -> compare with cf large)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k_experts
    xf = x.reshape(-1, d)
    logits = nn.dense(params["router"], xf).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    gates = jnp.zeros_like(probs)
    for i in range(k):
        gates = gates + gate_vals[:, i : i + 1] * jax.nn.one_hot(
            expert_ids[:, i], e, dtype=jnp.float32
        )
    outs = _expert_ffn(
        params["experts"],
        jnp.broadcast_to(xf, (e,) + xf.shape),
        cfg,
    )  # (E, T, d)
    y = jnp.einsum("te,etd->td", gates.astype(xf.dtype), outs)
    return y.reshape(b, s, d)
