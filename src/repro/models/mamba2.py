"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD forward: the sequence is split into chunks of length Q; within a
chunk the recurrence is computed as a (masked, decay-weighted) Q x Q
attention-like matmul (MXU-friendly), and a single (N, P) state per head is
carried across chunks with a lax.scan — O(S Q) work, O(S) memory, exactly
equivalent to the sequential recurrence

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T ,   y_t = C_t h_t + D x_t

(tested against the naive oracle in tests/test_mamba2.py).  The sequential
form is also implemented for single-token decode (O(1) per token, the reason
the `long_500k` cell is runnable for SSM/hybrid archs).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.models.config import ModelConfig


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    h = cfg.ssm_heads
    p = cfg.ssm_headdim
    g, n = cfg.ssm_groups, cfg.ssm_state
    conv_ch = di + 2 * g * n
    return di, h, p, g, n, conv_ch


def mamba_init(key, cfg: ModelConfig, *, dtype=jnp.float32):
    di, h, p, g, n, conv_ch = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    proj_dim = 2 * di + 2 * g * n + h  # z, x, B, C, dt
    # dt bias: softplus^-1 of dt ~ U[1e-3, 1e-1]
    rng = np.random.default_rng(0)
    dt = np.exp(
        rng.uniform(np.log(1e-3), np.log(1e-1), size=(h,))
    ).astype(np.float32)
    dt_bias = dt + np.log(-np.expm1(-dt))
    return {
        "in_proj": nn.dense_init(ks[0], d, proj_dim, use_bias=False,
                                 dtype=dtype),
        "conv": nn.fan_in_init()(ks[1], (cfg.ssm_conv, conv_ch), dtype),
        "A_log": jnp.asarray(
            np.log(rng.uniform(1.0, 16.0, size=(h,))), dtype=jnp.float32
        ),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.asarray(dt_bias),
        "norm": nn.rmsnorm_init(di, dtype=dtype),
        "out_proj": nn.dense_init(ks[2], di, d, use_bias=False, dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],  # (K, 1, C)
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out.astype(x.dtype)


def _split_proj(params, u, cfg: ModelConfig):
    di, h, p, g, n, conv_ch = _dims(cfg)
    zxbcdt = nn.dense(params["in_proj"], u)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + conv_ch]
    dt_raw = zxbcdt[..., di + conv_ch :]
    return z, xbc, dt_raw


def _post_conv(xbc, dt_raw, params, cfg: ModelConfig):
    di, h, p, g, n, conv_ch = _dims(cfg)
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    x = xbc[..., :di]
    B = xbc[..., di : di + g * n]
    C = xbc[..., di + g * n :]
    lead = x.shape[:-1]
    x = x.reshape(*lead, h, p)
    B = B.reshape(*lead, g, n)
    C = C.reshape(*lead, g, n)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"]
    )  # (..., h)
    return x, B, C, dt


def ssd_chunked(x, B, C, dt, A, *, chunk: int,
                h0: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x: (b, S, h, p); B, C: (b, S, g, n); dt: (b, S, h); A: (h,) negative.
    Returns y: (b, S, h, p) and final state (b, h, n, p).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    q = chunk

    xr = x.reshape(b, nc, q, h, p).astype(jnp.float32)
    Br = B.reshape(b, nc, q, g, n).astype(jnp.float32)
    Cr = C.reshape(b, nc, q, g, n).astype(jnp.float32)
    dtr = dt.reshape(b, nc, q, h)

    l = dtr * A  # log decay, (b,nc,q,h), negative
    cl = jnp.cumsum(l, axis=2)  # inclusive
    cl_last = cl[:, :, -1:, :]  # (b,nc,1,h)

    dx = xr * dtr[..., None]  # dt-weighted inputs

    # intra-chunk: scores_ij = (C_i . B_j) * exp(cl_i - cl_j) * [j <= i]
    cb = jnp.einsum("bcqgn,bckgn->bcgqk", Cr, Br)  # (b,nc,g,q,k)
    cb = jnp.repeat(cb, hg, axis=2)  # group -> heads: (b,nc,h,q,k)
    decay = jnp.exp(
        cl[:, :, :, None, :].transpose(0, 1, 4, 2, 3)
        - cl[:, :, None, :, :].transpose(0, 1, 4, 2, 3)
    )  # (b,nc,h,q,k) = exp(cl_i - cl_j)
    mask = jnp.tril(jnp.ones((q, q), bool))
    scores = jnp.where(mask, cb * decay, 0.0)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores, dx)

    # chunk summary state: sum_j exp(cl_last - cl_j) B_j (dx_j)^T
    decay_end = jnp.exp(cl_last - cl)  # (b,nc,q,h)
    Bh = jnp.repeat(Br, hg, axis=3)  # (b,nc,q,h,n): group -> heads
    chunk_state = jnp.einsum(
        "bcqhn,bcqhp,bcqh->bchnp", Bh, dx, decay_end
    )

    # carry states across chunks
    h_init = (
        jnp.zeros((b, h, n, p), jnp.float32) if h0 is None
        else h0.astype(jnp.float32)
    )
    chunk_decay = jnp.exp(cl_last[:, :, 0, :])  # (b,nc,h)

    def step(hc, inputs):
        cs, cd = inputs  # (b,h,n,p), (b,h)
        h_next = hc * cd[:, :, None, None] + cs
        return h_next, hc  # emit state at chunk START

    cs_seq = jnp.moveaxis(chunk_state, 1, 0)  # (nc,b,h,n,p)
    cd_seq = jnp.moveaxis(chunk_decay, 1, 0)  # (nc,b,h)
    h_final, h_starts = jax.lax.scan(step, h_init, (cs_seq, cd_seq))
    h_starts = jnp.moveaxis(h_starts, 0, 1)  # (b,nc,h,n,p)

    # inter-chunk: y_i += exp(cl_i) * C_i . h_start
    Ch = jnp.repeat(Cr, hg, axis=3)  # (b,nc,q,h,n)
    y_inter = jnp.einsum(
        "bcqhn,bchnp,bcqh->bcqhp", Ch, h_starts, jnp.exp(cl)
    )
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, h_final


def ssd_sequential(x, B, C, dt, A, *, h0=None):
    """Naive O(S) sequential recurrence — oracle + decode path."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    hstate = (
        jnp.zeros((b, h, n, p), jnp.float32) if h0 is None
        else h0.astype(jnp.float32)
    )

    def step(hs, t):
        xt, Bt, Ct, dtt = t  # (b,h,p), (b,g,n), (b,g,n), (b,h)
        a = jnp.exp(dtt * A)  # (b,h)
        Bh = jnp.repeat(Bt, hg, axis=1)  # (b,h,n)
        Ch = jnp.repeat(Ct, hg, axis=1)
        upd = jnp.einsum("bhn,bhp->bhnp", Bh, xt * dtt[..., None])
        hs = hs * a[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", Ch, hs)
        return hs, y

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(B.astype(jnp.float32), 1, 0),
        jnp.moveaxis(C.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, hstate, xs)
    return jnp.moveaxis(ys, 0, 1), h_final


def mamba_apply(params, u: jax.Array, cfg: ModelConfig,
                *, chunked: bool = True):
    """Full-sequence forward. u: (B, S, d_model)."""
    di, h, p, g, n, conv_ch = _dims(cfg)
    z, xbc, dt_raw = _split_proj(params, u, cfg)
    xbc = _causal_conv(xbc, params["conv"])
    x, B, C, dt = _post_conv(xbc, dt_raw, params, cfg)
    A = -jnp.exp(params["A_log"])
    if chunked and u.shape[1] % cfg.ssm_chunk == 0 and u.shape[1] > 1:
        y, _ = ssd_chunked(x, B, C, dt, A, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_sequential(x, B, C, dt, A)
    y = y + params["D"][:, None] * x.astype(jnp.float32)
    y = y.reshape(*u.shape[:-1], di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = nn.rmsnorm(params["norm"], y)
    return nn.dense(params["out_proj"], y.astype(u.dtype))


def mamba_cache_shapes(cfg: ModelConfig, batch: int):
    di, h, p, g, n, conv_ch = _dims(cfg)
    return {
        "ssm": (batch, h, n, p),
        "conv": (batch, cfg.ssm_conv - 1, conv_ch),
    }


def mamba_decode(params, u: jax.Array, cfg: ModelConfig, cache):
    """One token. u: (B, 1, d). cache: {'ssm': (B,h,n,p), 'conv': (B,K-1,C)}."""
    di, h, p, g, n, conv_ch = _dims(cfg)
    z, xbc, dt_raw = _split_proj(params, u, cfg)
    # causal conv over (stored window + current)
    win = jnp.concatenate([cache["conv"], xbc.astype(jnp.float32)], axis=1)
    w = params["conv"].astype(jnp.float32)  # (K, C)
    conv_out = jnp.einsum("bkc,kc->bc", win, w)[:, None, :]
    new_conv = win[:, 1:, :]
    x, B, C, dt = _post_conv(conv_out, dt_raw, params, cfg)
    A = -jnp.exp(params["A_log"])
    y, h_new = ssd_sequential(x, B, C, dt, A, h0=cache["ssm"])
    y = y + params["D"][:, None] * x.astype(jnp.float32)
    y = y.reshape(*u.shape[:-1], di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = nn.rmsnorm(params["norm"], y)
    out = nn.dense(params["out_proj"], y.astype(u.dtype))
    return out, {"ssm": h_new, "conv": new_conv}
