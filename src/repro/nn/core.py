"""Core functional layers: dense, norms, embeddings, initializers."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Initializer = Callable[[jax.Array, tuple[int, ...], jnp.dtype], jax.Array]


def truncated_normal_init(stddev: float = 1.0) -> Initializer:
    def init(key, shape, dtype=jnp.float32):
        unscaled = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
        return (unscaled * stddev).astype(dtype)

    return init


def fan_in_init(scale: float = 1.0) -> Initializer:
    """LeCun-style: stddev = scale / sqrt(fan_in) with fan_in = shape[0]."""

    def init(key, shape, dtype=jnp.float32):
        stddev = scale / np.sqrt(max(1, shape[0]))
        unscaled = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
        return (unscaled * stddev).astype(dtype)

    return init


def zeros_init() -> Initializer:
    return lambda key, shape, dtype=jnp.float32: jnp.zeros(shape, dtype)


def ones_init() -> Initializer:
    return lambda key, shape, dtype=jnp.float32: jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def dense_init(
    key,
    in_dim: int,
    out_dim: int,
    *,
    use_bias: bool = True,
    dtype=jnp.float32,
    kernel_init: Initializer | None = None,
):
    kernel_init = kernel_init or fan_in_init()
    p = {"kernel": kernel_init(key, (in_dim, out_dim), dtype)}
    if use_bias:
        p["bias"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(params, x):
    y = x @ params["kernel"].astype(x.dtype)
    if "bias" in params:
        y = y + params["bias"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, dim: int, *, dtype=jnp.float32):
    return {"embedding": truncated_normal_init(1.0)(key, (vocab, dim), dtype)}


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, *, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, *, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def batchnorm_init(dim: int, *, dtype=jnp.float32):
    params = {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    state = {"mean": jnp.zeros((dim,), jnp.float32),
             "var": jnp.ones((dim,), jnp.float32)}
    return params, state


def batchnorm(params, state, x, *, train: bool, momentum: float = 0.99,
              eps: float = 1e-5):
    """Feature-wise batchnorm over all leading dims. Returns (y, new_state).

    At pod scale the statistics are per-host-batch (standard large-scale
    practice); the running stats are carried in the model state pytree.
    """
    x32 = x.astype(jnp.float32)
    if train:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x32, axis=axes)
        var = jnp.var(x32, axis=axes)
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mean,
            "var": momentum * state["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Tree utilities
# ---------------------------------------------------------------------------

def tree_paths(tree) -> list[tuple[str, jax.Array]]:
    """Flatten a params tree to ('a/b/c', leaf) pairs."""
    out = []
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, leaf))
    return out


def param_count(tree) -> int:
    return sum(int(np.prod(l.shape)) for _, l in tree_paths(tree))
