"""Minimal functional NN substrate (no external deps).

Params are nested dicts of jax arrays; every layer is an (init, apply) pair.
Mutable per-layer state (batchnorm running stats) is threaded explicitly as a
separate pytree so train/serve steps stay pure.  Partition rules match on
param-tree paths (see repro.distributed.sharding).
"""

from repro.nn.core import (  # noqa: F401
    Initializer,
    dense,
    dense_init,
    embedding_init,
    fan_in_init,
    layernorm,
    layernorm_init,
    rmsnorm,
    rmsnorm_init,
    batchnorm,
    batchnorm_init,
    truncated_normal_init,
    zeros_init,
    ones_init,
    param_count,
    tree_paths,
)
