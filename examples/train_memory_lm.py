"""End-to-end driver example: train the paper's LRAM-BERT on masked LM.

    # full paper model (74M params), a few hundred steps:
    PYTHONPATH=src python examples/train_memory_lm.py --full

    # quick CPU demo (reduced config, ~2 min):
    PYTHONPATH=src python examples/train_memory_lm.py

Wraps repro.launch.train: checkpointing every 100 steps (auto-resume on
relaunch), fact-recall eval, the paper's 10x memory learning rate, and the
baseline-vs-LRAM comparison from Table 2 at the chosen scale.
"""

import argparse

from repro.launch import train


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="paper-size lram-bert-small (74M params)")
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--arch", default="lram-bert-small")
    p.add_argument("--ckpt-dir", default="/tmp/lram_bert_ckpt")
    args = p.parse_args()

    argv = [
        "--arch", args.arch,
        "--steps", str(args.steps),
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
        "--eval-every", "100",
        "--log-every", "20",
        "--memory-lr-mult", "10",   # paper §3.2: 1e-3 vs 1e-4
    ]
    if args.full:
        argv += ["--batch", "16", "--seq", "128"]
    else:
        argv += ["--smoke", "--batch", "16", "--seq", "64"]
    print("launching:", " ".join(argv))
    train.main(argv)


if __name__ == "__main__":
    main()
