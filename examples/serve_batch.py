"""Batched serving example: the continuous-batching engine on any arch.

    PYTHONPATH=src python examples/serve_batch.py --arch yi-9b
    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-1.3b --gen 32
    PYTHONPATH=src python examples/serve_batch.py --arch lram-tiered \
        --mode static   # legacy fixed-batch loop for comparison

Uses the reduced (smoke) configs so it runs on CPU; the same decode_step
the engine ticks is what the decode_32k / long_500k dry-run cells lower at
production scale.  See docs/serving.md for the engine design.
"""

import argparse

from repro.launch import serve


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi-9b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--mode", choices=["continuous", "static"],
                   default="continuous")
    args = p.parse_args()
    serve.main([
        "--arch", args.arch, "--smoke",
        "--mode", args.mode,
        "--batch", str(args.batch),
        "--prompt-len", "16",
        "--gen", str(args.gen),
    ])


if __name__ == "__main__":
    main()
