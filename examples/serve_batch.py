"""Batched serving example: prefill + greedy decode on any assigned arch.

    PYTHONPATH=src python examples/serve_batch.py --arch yi-9b
    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-1.3b --gen 32

Uses the reduced (smoke) configs so it runs on CPU; the same decode_step is
what the decode_32k / long_500k dry-run cells lower at production scale.
"""

import argparse

from repro.launch import serve


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi-9b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--gen", type=int, default=16)
    args = p.parse_args()
    serve.main([
        "--arch", args.arch, "--smoke",
        "--batch", str(args.batch),
        "--prompt-len", "16",
        "--gen", str(args.gen),
    ])


if __name__ == "__main__":
    main()
