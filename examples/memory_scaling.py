"""The paper's headline plot (Fig 3): layer cost vs memory size.

    PYTHONPATH=src python examples/memory_scaling.py

Times the LRAM layer forward at N = 2^16 .. 2^20 and PKM at matched sizes:
LRAM stays flat (O(1)); PKM grows ~ sqrt(N).  ASCII plot, CPU wall-clock.
The sweep also times the int8-quantized layer and closes with the capacity
table: effective bytes/entry and the largest N affordable at a fixed
memory budget, fp32 vs int8 (see docs/architecture.md, `repro.quant`).
"""

import time

import jax
import numpy as np

from repro.core import lram, pkm
from repro import quant

BATCH = 256
KEY = jax.random.PRNGKey(0)


def timed(f, *args, iters=5):
    jax.block_until_ready(f(*args))
    ts = []
    for _ in range(iters):
        t0 = time.time()
        jax.block_until_ready(f(*args))
        ts.append(time.time() - t0)
    return float(np.median(ts)) * 1e3


def main():
    print(f"{'N':>10} {'LRAM ms':>9} {'LRAM-q8 ms':>11} {'PKM ms':>9}")
    results = []
    for log2 in (16, 17, 18, 19, 20):
        cfg = lram.LRAMConfig(log2_locations=log2, m=64, heads=8,
                              query_norm="rms")
        params, state = lram.lram_init(KEY, cfg)
        x = jax.random.normal(KEY, (BATCH, cfg.in_dim))
        f = jax.jit(lambda p, x, c=cfg, s=state:
                    lram.lram_apply(p, s, x, c)[0])
        t_lram = timed(f, params, x)

        qcfg = lram.LRAMConfig(log2_locations=log2, m=64, heads=8,
                               query_norm="rms", table_quant="int8")
        qparams, qstate = lram.lram_init(KEY, qcfg)
        fq = jax.jit(lambda p, x, c=qcfg, s=qstate:
                     lram.lram_apply(p, s, x, c)[0])
        t_lram_q8 = timed(fq, qparams, x)

        n_keys = int(2 ** (log2 / 2))
        pcfg = pkm.PKMConfig(n_keys=n_keys, heads=8, key_dim=64,
                             value_dim=512, top_k=32, query_norm="none")
        pparams, pstate = pkm.pkm_init(KEY, 512, pcfg)
        xp = jax.random.normal(KEY, (BATCH, 512))
        fp = jax.jit(lambda p, x, c=pcfg, s=pstate:
                     pkm.pkm_apply(p, s, x, c)[0])
        t_pkm = timed(fp, pparams, xp)
        results.append((log2, t_lram, t_pkm))
        print(f"{2**log2:>10} {t_lram:>9.2f} {t_lram_q8:>11.2f} {t_pkm:>9.2f}")

    tmax = max(max(r[1], r[2]) for r in results)
    print("\n  LRAM (#)  vs PKM (*)   — flat vs sqrt(N)")
    for log2, tl, tp in results:
        bars_l = int(40 * tl / tmax)
        bars_p = int(40 * tp / tmax)
        print(f"2^{log2} |{'#' * bars_l}")
        print(f"     |{'*' * bars_p}")

    # capacity at fixed budget: the other axis of the headline claim.
    # bytes/entry fixes the largest N a memory budget can hold, and int8
    # payloads + per-row fp32 scales cut it ~3.8x (repro.quant).
    m = 64
    print(f"\n{'budget':>8} {'fp32 B/entry':>13} {'int8 B/entry':>13} "
          f"{'max N fp32':>12} {'max N int8':>12}")
    for gib in (1, 16, 256):
        budget = gib * 2**30
        bpe_fp = quant.bytes_per_entry(m, None)
        bpe_q8 = quant.bytes_per_entry(m, "int8")
        print(f"{gib:>6}GiB {bpe_fp:>13} {bpe_q8:>13} "
              f"{float(budget // bpe_fp):>12.2e} "
              f"{float(budget // bpe_q8):>12.2e}")
    print(f"\nint8 capacity multiplier at fixed budget: "
          f"{quant.bytes_per_entry(m, None) / quant.bytes_per_entry(m, 'int8'):.2f}x")


if __name__ == "__main__":
    main()
