"""Quickstart: the LRAM layer in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a lattice memory, demonstrates the O(1) lookup + the interpolation
property (phi(k) = v_k), and trains the layer to memorise a random function
— the differentiable-RAM behaviour the paper is named for.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import indexing, lram

key = jax.random.PRNGKey(0)

# a memory with 2^16 slots of 16-dim values, 4 query heads
cfg = lram.LRAMConfig(log2_locations=16, m=16, heads=4, query_norm="rms")
params, state = lram.lram_init(key, cfg)
print(f"memory: {cfg.num_locations} locations x {cfg.m} dims "
      f"({cfg.num_params/1e6:.1f}M params), lookup touches "
      f"<= {cfg.top_k} rows per head — O(1) regardless of size")

# ---- lookup ----------------------------------------------------------------
x = jax.random.normal(key, (8, cfg.in_dim))
y, _ = lram.lram_apply(params, state, x, cfg)
print("lookup:", x.shape, "->", y.shape)

# ---- interpolation property: a query ON a lattice point returns its value --
spec = cfg.torus_spec
target = 12345
pt = indexing.decode_index(np.array([target]), spec)[0].astype(np.float32)
idx, w = lram.indices_and_weights(jnp.asarray(pt[None]), spec, cfg.top_k)
print(f"query at lattice point {target}: weight on own slot = "
      f"{float(w.max()):.6f} (exactly 1 -> phi(k) = v_k)")

# ---- differentiable RAM: memorise 512 random (query -> value) pairs --------
qs = jax.random.normal(jax.random.PRNGKey(1), (512, cfg.in_dim))
vs = jax.random.normal(jax.random.PRNGKey(2), (512, cfg.out_dim))


def loss_fn(p):
    out, _ = lram.lram_apply(p, state, qs, cfg)
    return jnp.mean((out - vs) ** 2)


from repro import optim

opt_cfg = optim.OptimConfig(lr=3e-2, memory_lr_mult=10.0, grad_clip=0.0)
loss_grad = jax.jit(jax.value_and_grad(loss_fn))
p = params
opt_state = optim.adam_init(p)
for step in range(300):
    loss, g = loss_grad(p)
    p, opt_state, _ = optim.adam_update(g, opt_state, p, opt_cfg)
    if step % 75 == 0 or step == 299:
        print(f"step {step:4d}  write-then-read mse {float(loss):.5f}")

# sparse-update check: how many of the 65536 rows did training touch?
delta = jnp.abs(p["values"] - params["values"]).sum(axis=1)
print(f"rows updated: {int((delta > 0).sum())} / {cfg.num_locations} "
      "(input-dependent sparse writes)")
