"""LRAM layer behaviour: shapes, sparsity, interpolation, O(1) access."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import indexing, lram

KEY = jax.random.PRNGKey(0)
CFG = lram.LRAMConfig(log2_locations=16, m=8, heads=4, query_norm="rms")


@pytest.fixture(scope="module")
def layer():
    params, state = lram.lram_init(KEY, CFG)
    return params, state


def test_shapes_and_finiteness(layer):
    params, state = layer
    x = jax.random.normal(KEY, (3, 5, CFG.in_dim))
    y, _ = lram.lram_apply(params, state, x, CFG)
    assert y.shape == (3, 5, CFG.out_dim)
    assert bool(jnp.isfinite(y).all())


def test_value_gradient_sparsity(layer):
    """dL/dvalues touches at most top_k * heads rows per example."""
    params, state = layer
    batch = 16
    x = jax.random.normal(KEY, (batch, CFG.in_dim))

    def loss(p):
        y, _ = lram.lram_apply(p, state, x, CFG)
        return jnp.sum(y**2)

    g = jax.grad(loss)(params)["values"]
    nnz = int((jnp.abs(g).sum(1) > 0).sum())
    assert 0 < nnz <= CFG.top_k * CFG.heads * batch


def test_interpolation_property():
    """phi(k) = v_k: a query exactly on a lattice point returns its value."""
    spec = CFG.torus_spec
    target = 4321
    pt = indexing.decode_index(np.array([target]), spec)[0].astype(np.float32)
    idx, w = lram.indices_and_weights(jnp.asarray(pt[None]), spec, CFG.top_k)
    idx, w = np.asarray(idx), np.asarray(w)
    assert w[0].sum() == pytest.approx(1.0, abs=1e-5)
    assert idx[0, np.argmax(w[0])] == target
    assert w[0].max() == pytest.approx(1.0, abs=1e-5)


def test_gather_interp_matches_dense_einsum(rng):
    values = jnp.asarray(rng.normal(size=(1000, 16)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 1000, size=(4, 7, 32)))
    w = jnp.asarray(rng.normal(size=(4, 7, 32)).astype(np.float32))
    out = lram.gather_interp(values, idx, w)
    onehot = jax.nn.one_hot(idx, 1000)
    expected = jnp.einsum("...k,...kn,nm->...m", w, onehot, values)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), atol=1e-4)


def test_output_scales_with_input_magnitude(layer):
    """theta(lambda z) = lambda theta(z) survives through the whole layer
    (with rms query norm disabled — use query_norm='none')."""
    cfg = lram.LRAMConfig(log2_locations=16, m=8, heads=4, query_norm="none")
    params, state = lram.lram_init(KEY, cfg)
    x = jax.random.normal(KEY, (8, cfg.in_dim))
    y1, _ = lram.lram_apply(params, state, x, cfg)
    y2, _ = lram.lram_apply(params, state, 2.0 * x, cfg)
    np.testing.assert_allclose(np.asarray(2.0 * y1), np.asarray(y2), atol=1e-4)


def test_flops_independent_of_memory_size():
    """Table 3/4: compiled FLOPs for the lookup must not grow with N."""
    flops = {}
    for log2 in (16, 20):
        cfg = lram.LRAMConfig(log2_locations=log2, m=8, heads=4,
                              query_norm="rms")
        params, state = lram.lram_init(jax.random.PRNGKey(1), cfg)
        x = jax.random.normal(KEY, (64, cfg.in_dim))

        def f(v, x, cfg=cfg, params=params, state=state):
            p = dict(params)
            p["values"] = v
            y, _ = lram.lram_apply(p, state, x, cfg)
            return y

        lowered = jax.jit(f).lower(params["values"], x)
        cost = lowered.compile().cost_analysis()
        if isinstance(cost, list):  # newer jaxlib returns [dict]
            cost = cost[0]
        flops[log2] = cost.get("flops", 0.0)
    assert flops[20] <= flops[16] * 1.02 + 1e5  # O(1) in N


def test_memffn_block_shapes():
    width = 64
    cfg = lram.memffn_config(width, 16, query_norm="rms")
    assert cfg.in_dim == width and cfg.out_dim == 4 * width
    params, state = lram.memffn_init(KEY, width, cfg)
    x = jax.random.normal(KEY, (6, width))
    y, _ = lram.memffn_apply(params, state, x, cfg)
    assert y.shape == (6, width)


def test_batchnorm_query_path():
    cfg = lram.LRAMConfig(log2_locations=16, m=8, heads=4, query_norm="batch")
    params, state = lram.lram_init(KEY, cfg)
    x = jax.random.normal(KEY, (32, cfg.in_dim))
    y, st1 = lram.lram_apply(params, state, x, cfg, train=True)
    # running stats moved
    assert not np.allclose(np.asarray(st1["qnorm"]["mean"]), 0.0)
    y2, st2 = lram.lram_apply(params, st1, x, cfg, train=False)
    assert st2["qnorm"] is st1["qnorm"] or np.allclose(
        np.asarray(st2["qnorm"]["mean"]), np.asarray(st1["qnorm"]["mean"])
    )
    assert bool(jnp.isfinite(y2).all())


def test_access_tracking_for_utilisation(layer):
    params, state = layer
    x = jax.random.normal(KEY, (16, CFG.in_dim))
    y, _, (idx, w) = lram.lram_apply(
        params, state, x, CFG, return_access=True
    )
    assert idx.shape == (16, CFG.heads, CFG.top_k)
    assert w.shape == idx.shape
    assert int(idx.min()) >= 0 and int(idx.max()) < CFG.num_locations
