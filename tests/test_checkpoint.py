"""Checkpointing: roundtrip, atomicity, corruption fallback, async, retention."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


@pytest.fixture
def tree(rng):
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)).astype("f")),
                   "b": jnp.asarray(rng.normal(size=(4,)).astype("f"))},
        "opt": {"step": jnp.asarray(17, jnp.int32)},
    }


def test_roundtrip(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(100, tree)
    step, restored = mgr.restore(tree)
    assert step == 100
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        tree, restored,
    )


def test_latest_and_retention(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_corrupted_checkpoint_falls_back(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, tree)
    mgr.save(2, tree)
    # corrupt the newest: truncate a leaf file
    d = os.path.join(str(tmp_path), "step_000000000002")
    leaf = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, leaf), "wb") as f:
        f.write(b"garbage")
    step, restored = mgr.restore(tree)
    assert step == 1  # silently fell back to the newest VALID checkpoint
    assert restored is not None


def test_interrupted_save_leaves_no_partial(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    # simulate a crash mid-save: a lingering .tmp dir must be ignored
    os.makedirs(os.path.join(str(tmp_path), "step_000000000002.tmp"))
    assert mgr.latest_step() == 1
    step, _ = mgr.restore(tree)
    assert step == 1


def test_async_save(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5
    step, restored = mgr.restore(tree)
    assert step == 5


def test_restore_with_dtype_cast(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    like = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
        if a.dtype == jnp.float32 else a,
        tree,
    )
    step, restored = mgr.restore(like)
    assert restored["params"]["w"].dtype == jnp.bfloat16


def test_missing_leaf_raises(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    bigger = dict(tree)
    bigger["extra"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        mgr.restore(bigger)


def test_manifest_contents(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(9, tree)
    with open(os.path.join(str(tmp_path), "step_000000000009",
                           "manifest.json")) as f:
        man = json.load(f)
    assert man["step"] == 9
    assert "params/w" in man["leaves"]
    assert man["leaves"]["params/w"]["shape"] == [8, 4]
