"""Checkpointing: roundtrip, atomicity, corruption fallback, async,
retention, and the size-mismatch paths (grow-on-restore / CheckpointError)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointError, CheckpointManager


@pytest.fixture
def tree(rng):
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)).astype("f")),
                   "b": jnp.asarray(rng.normal(size=(4,)).astype("f"))},
        "opt": {"step": jnp.asarray(17, jnp.int32)},
    }


def test_roundtrip(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(100, tree)
    step, restored = mgr.restore(tree)
    assert step == 100
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        tree, restored,
    )


def test_latest_and_retention(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_corrupted_checkpoint_falls_back(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, tree)
    mgr.save(2, tree)
    # corrupt the newest: truncate a leaf file
    d = os.path.join(str(tmp_path), "step_000000000002")
    leaf = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, leaf), "wb") as f:
        f.write(b"garbage")
    step, restored = mgr.restore(tree)
    assert step == 1  # silently fell back to the newest VALID checkpoint
    assert restored is not None


def test_interrupted_save_leaves_no_partial(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    # simulate a crash mid-save: a lingering .tmp dir must be ignored
    os.makedirs(os.path.join(str(tmp_path), "step_000000000002.tmp"))
    assert mgr.latest_step() == 1
    step, _ = mgr.restore(tree)
    assert step == 1


def test_async_save(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5
    step, restored = mgr.restore(tree)
    assert step == 5


def test_restore_with_dtype_cast(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    like = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
        if a.dtype == jnp.float32 else a,
        tree,
    )
    step, restored = mgr.restore(like)
    assert restored["params"]["w"].dtype == jnp.bfloat16


def test_missing_leaf_raises(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    bigger = dict(tree)
    bigger["extra"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        mgr.restore(bigger)


def test_manifest_contents(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(9, tree)
    with open(os.path.join(str(tmp_path), "step_000000000009",
                           "manifest.json")) as f:
        man = json.load(f)
    assert man["step"] == 9
    assert "params/w" in man["leaves"]
    assert man["leaves"]["params/w"]["shape"] == [8, 4]


# ---------------------------------------------------------------------------
# size-mismatch paths: grow-on-restore vs a clear CheckpointError
# ---------------------------------------------------------------------------


def test_grow_on_restore_into_larger_store(tmp_path, rng):
    """A smaller tiered checkpoint streams into a larger store: old shards
    land at their ids, appended shards alias their coarse-lattice parent
    (j mod old_N) — matching what repro.memctl.grow would have built."""
    from repro.memstore import TieredSpec, TieredValueStore

    dense = rng.normal(size=(2048, 8)).astype(np.float32)
    spec = TieredSpec(shard_rows=256, cache_slots=2)
    small = TieredValueStore.from_dense(dense, spec)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"values": small})

    big = TieredValueStore(4096, 8, spec)
    step, _ = mgr.restore({"values": big})
    assert step == 1
    got = big.to_dense()
    np.testing.assert_array_equal(got[:2048], dense)
    np.testing.assert_array_equal(got[2048:], dense)  # alias copy


def test_grow_on_restore_dense_leaf(tmp_path, rng):
    """A dense memory-table leaf grows on restore by the same alias rule."""
    arr = rng.normal(size=(1024, 8)).astype(np.float32)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"lram": {"values": jnp.asarray(arr)}})
    like = {"lram": {"values": jnp.zeros((2048, 8), jnp.float32)}}
    step, restored = mgr.restore(like)
    assert step == 1
    got = np.asarray(restored["lram"]["values"])
    np.testing.assert_array_equal(got[:1024], arr)
    np.testing.assert_array_equal(got[1024:], arr)


def test_restore_shrink_raises_checkpoint_error(tmp_path, rng):
    """The reverse direction — a larger checkpoint into a smaller table —
    is an explicit CheckpointError (raised through the fallback loop, not
    swallowed), for stores and dense leaves alike."""
    from repro.memstore import TieredSpec, TieredValueStore

    dense = rng.normal(size=(4096, 8)).astype(np.float32)
    spec = TieredSpec(shard_rows=256, cache_slots=2)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"values": TieredValueStore.from_dense(dense, spec)})
    with pytest.raises(CheckpointError, match="shrink"):
        mgr.restore({"values": TieredValueStore(2048, 8, spec)})

    mgr2 = CheckpointManager(str(tmp_path / "d"))
    mgr2.save(1, {"lram": {"values": jnp.asarray(dense)}})
    with pytest.raises(CheckpointError, match="shrink"):
        mgr2.restore({"lram": {"values": jnp.zeros((2048, 8))}})


def test_restore_non_table_shape_mismatch_raises(tmp_path, rng):
    """Non-memory-table leaves never grow silently: any shape mismatch is
    a clear CheckpointError instead of a mis-shaped return value — and
    the alias rule applies only to LRAM tables, NOT to coincidental
    `values` leaves like pkm/values (their rows have no lattice parent)."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.asarray(rng.normal(size=(8, 4)).astype("f"))})
    with pytest.raises(CheckpointError, match="shape mismatch"):
        mgr.restore({"w": jnp.zeros((16, 4))})

    mgr2 = CheckpointManager(str(tmp_path / "p"))
    mgr2.save(1, {"pkm": {"values": jnp.asarray(
        rng.normal(size=(8, 4)).astype("f"))}})
    with pytest.raises(CheckpointError, match="shape mismatch"):
        mgr2.restore({"pkm": {"values": jnp.zeros((16, 4))}})


def test_restore_shard_geometry_mismatch_raises(tmp_path, rng):
    from repro.memstore import TieredSpec, TieredValueStore

    dense = rng.normal(size=(2048, 8)).astype(np.float32)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"values": TieredValueStore.from_dense(
        dense, TieredSpec(shard_rows=256, cache_slots=2))})
    other = TieredValueStore(2048, 8,
                             TieredSpec(shard_rows=512, cache_slots=2))
    with pytest.raises(CheckpointError, match="geometry"):
        mgr.restore({"values": other})


def test_grow_on_restore_quantized_payload_exact(tmp_path, rng):
    """Grow-on-restore of a quantized store copies payload + scales into
    the appended shards — bit-exact, like memctl.grow itself."""
    from repro.memstore import TieredSpec, TieredValueStore

    dense = rng.normal(size=(1024, 8)).astype(np.float32)
    spec = TieredSpec(shard_rows=256, cache_slots=2, quant="int8")
    small = TieredValueStore.from_dense(dense, spec)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"values": small})
    big = TieredValueStore(2048, 8, spec)
    mgr.restore({"values": big})
    got = big.to_dense()
    np.testing.assert_array_equal(got[:1024], small.to_dense())
    np.testing.assert_array_equal(got[1024:], small.to_dense())
