"""Multi-device behaviour (subprocess with 8 fake devices): sharded LRAM
lookup, pipeline parallelism, compressed collectives, sharded-vs-single
train-step equivalence, elastic checkpoint reshape, fault monitor."""

import pytest

import textwrap


from conftest import run_in_subprocess

from repro.distributed import fault


# ---------------------------------------------------------------------------
# in-process: fault-tolerance units (no devices needed)
# ---------------------------------------------------------------------------

def test_heartbeat_monitor_flags_stragglers():
    mon = fault.HeartbeatMonitor(num_hosts=4)
    for step in range(10):
        for h in range(4):
            mon.heartbeat(h, 1.0 if h != 2 else 3.0, now=float(step))
    assert mon.stragglers() == [2]
    assert mon.healthy(now=10.0)


def test_heartbeat_monitor_detects_dead_host():
    mon = fault.HeartbeatMonitor(num_hosts=3, timeout_s=5.0)
    mon.heartbeat(0, 1.0, now=0.0)
    mon.heartbeat(1, 1.0, now=0.0)
    # host 2 never reports; hosts 0/1 keep reporting
    mon.heartbeat(0, 1.0, now=6.0)
    mon.heartbeat(1, 1.0, now=6.0)
    assert mon.dead_hosts(now=7.0) == [2]


def test_step_timer_outliers():
    t = fault.StepTimer()
    for _ in range(20):
        t.record(0.1)
    assert t.is_outlier(0.5)
    assert not t.is_outlier(0.15)


# ---------------------------------------------------------------------------
# subprocess: 8 fake devices
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_lram_lookup_matches_reference():
    run_in_subprocess(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import indexing, lram
        from repro.distributed.sharded_lram import sharded_gather_interp
        from repro.kernels import ref

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        spec = indexing.choose_torus(16)
        rng = np.random.default_rng(0)
        values = jnp.asarray(rng.normal(size=(spec.num_locations, 16))
                             .astype(np.float32))
        q = jnp.asarray(rng.uniform(0, 8, size=(8, 3, 8)).astype(np.float32))
        idx, w = lram.indices_and_weights(q, spec, 32)
        want = ref.gather_interp_ref(values, idx, w)
        interp = sharded_gather_interp(mesh, axis="model")
        got = interp(values, idx, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

        # gradients flow through the shard_map path
        def loss(v):
            return jnp.sum(interp(v, idx, w) ** 2)
        g = jax.grad(loss)(values)
        def loss_ref(v):
            return jnp.sum(ref.gather_interp_ref(v, idx, w) ** 2)
        g_ref = jax.grad(loss_ref)(values)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-4, atol=1e-4)
        print("sharded lram OK")
    """), devices=8)


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    run_in_subprocess(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply

        mesh = jax.make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        n_stages, d = 4, 16
        Ws = jnp.asarray(rng.normal(size=(n_stages, d, d))
                         .astype(np.float32) * 0.3)
        x = jnp.asarray(rng.normal(size=(8, d)).astype(np.float32))

        def stage(W, x):
            return jnp.tanh(x @ W)

        seq = x
        for i in range(n_stages):
            seq = stage(Ws[i], seq)
        out = pipeline_apply(stage, Ws, x, mesh=mesh, axis="pod",
                             num_microbatches=4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(seq),
                                   rtol=1e-5, atol=1e-5)
        print("pipeline OK")
    """), devices=4)


@pytest.mark.slow
def test_compressed_psum_close_to_exact():
    run_in_subprocess(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed._compat import shard_map
        from repro.distributed.collectives import compressed_psum

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))

        def f(xl):
            return compressed_psum(xl, "data")

        out = shard_map(f, mesh=mesh, in_specs=(P("data", None),),
                        out_specs=P(None))(x)
        exact = x.sum(0)
        err = np.abs(np.asarray(out[0]) - np.asarray(exact)).max()
        scale = float(jnp.abs(x).max()) / 127.0
        assert err <= 8 * scale + 1e-6, (err, scale)
        print("compressed psum OK, err", err)
    """), devices=8)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    run_in_subprocess(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs, data, optim
        from repro.distributed import sharding
        from repro.launch.train import build_train_step
        from repro.models import transformer

        cfg = configs.get_smoke_config("yi-9b")
        dcfg = data.DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                               global_batch=8, objective="clm")
        opt_cfg = optim.OptimConfig(lr=1e-3)
        key = jax.random.PRNGKey(0)
        params, mstate = transformer.init(key, cfg)
        batch = jax.tree.map(jnp.asarray, data.get_batch(dcfg, step=0))

        # single device (donates params -> re-init below for the mesh path)
        step1 = build_train_step(cfg, opt_cfg)
        p1, o1, _, _, m1 = step1(params, optim.adam_init(params), mstate,
                                  jnp.zeros(()), batch)

        # sharded over 4x2 mesh (same PRNG key -> identical init)
        params2, _ = transformer.init(key, cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ps = sharding.shard_params(params2, mesh)
        stepm = build_train_step(cfg, opt_cfg, mesh)
        p2, o2, _, _, m2 = stepm(ps, optim.adam_init(ps), mstate,
                                  jnp.zeros(()), batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
        diff = jax.tree.map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                        - b.astype(jnp.float32)).max()),
            p1, p2)
        worst = max(jax.tree.leaves(diff))
        assert worst < 5e-3, worst
        print("sharded == single-device OK, worst", worst)
    """), devices=8)


@pytest.mark.slow
def test_elastic_checkpoint_reshape():
    run_in_subprocess(textwrap.dedent("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.distributed import sharding

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)

        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        sharded = jax.device_put(
            tree["w"], NamedSharding(mesh_a, P("data", "model")))
        mgr.save(1, {"w": sharded})

        # restore onto a DIFFERENT mesh shape (elastic rescale 8 -> 2x4)
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        target = NamedSharding(mesh_b, P("model", "data"))
        step, restored = mgr.restore({"w": tree["w"]},
                                     sharding={"w": target})
        assert step == 1
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding == target
        print("elastic reshape OK")
    """), devices=8)


@pytest.mark.slow
def test_train_driver_failure_and_resume(tmp_path):
    """Kill the driver mid-run via injected failure; relaunch resumes from
    the checkpoint and finishes."""
    code = textwrap.dedent(f"""
        import sys
        from repro.distributed.fault import SimulatedFailure
        from repro.launch import train
        args = ["--arch", "lram-bert-baseline", "--smoke", "--steps", "12",
                "--batch", "2", "--seq", "32", "--ckpt-dir",
                r"{tmp_path}", "--ckpt-every", "4", "--log-every", "4"]
        try:
            train.main(args + ["--simulate-failure-at", "9"])
            raise SystemExit("expected SimulatedFailure")
        except SimulatedFailure:
            print("crashed as requested")
        train.main(args)  # relaunch: must resume from step 8 and finish
        print("resumed-and-finished")
    """)
    out = run_in_subprocess(code, devices=1, timeout=900)
    assert "crashed as requested" in out
    assert "resumed from step 8" in out
    assert "resumed-and-finished" in out
