"""Data pipeline: determinism, sharding, masking statistics."""

import numpy as np

from repro import data
from repro.data.synthetic import IGNORE, fact_eval_batch


def _cfg(**kw):
    base = dict(vocab_size=1000, seq_len=64, global_batch=16, kind="facts",
                objective="mlm")
    base.update(kw)
    return data.DataConfig(**base)


def test_deterministic_across_calls():
    cfg = _cfg()
    b1 = data.get_batch(cfg, step=7)
    b2 = data.get_batch(cfg, step=7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_different_steps_differ():
    cfg = _cfg()
    b1 = data.get_batch(cfg, step=1)
    b2 = data.get_batch(cfg, step=2)
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_shards_are_disjoint_and_sized():
    cfg = _cfg(global_batch=16)
    full_rows = set()
    for i in range(4):
        b = data.get_batch(cfg, step=3, shard=(i, 4))
        assert b["tokens"].shape == (4, 64)
        for row in b["tokens"]:
            full_rows.add(row.tobytes())
    assert len(full_rows) == 16  # no duplicated sequences across shards


def test_mlm_masking_statistics():
    cfg = _cfg(global_batch=64, seq_len=256)
    b = data.get_batch(cfg, step=0)
    frac = (b["labels"] != IGNORE).mean()
    assert 0.12 < frac < 0.18  # ~15%
    masked = b["labels"] != IGNORE
    mask_tok = (b["tokens"] == cfg.mask_token) & masked
    assert 0.7 < mask_tok.sum() / masked.sum() < 0.9  # ~80% [MASK]


def test_clm_labels_are_shifted():
    cfg = _cfg(objective="clm")
    b = data.get_batch(cfg, step=0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == IGNORE).all()


def test_fact_eval_batch_masks_only_values():
    cfg = _cfg()
    b = fact_eval_batch(cfg, n=32)
    labeled = (b["labels"] != IGNORE).sum(axis=1)
    np.testing.assert_array_equal(labeled, np.full(32, 3))  # value trigram
    # masked positions carry the mask token
    m = b["labels"] != IGNORE
    assert (b["tokens"][m] == cfg.mask_token).all()


def test_facts_actually_planted():
    cfg = _cfg(fact_density=1.0)
    table = data.make_fact_table(cfg)
    raw = data.DataConfig(**{**cfg.__dict__, "objective": "clm"})
    b = data.get_batch(raw, step=5, table=table)
    keys = {tuple(k) for k, v in table}
    found = 0
    for row in b["tokens"]:
        for i in range(len(row) - 6):
            if tuple(row[i : i + 3]) in keys:
                found += 1
                break
    assert found >= 12  # most of 16 sequences carry a planted fact
