"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train-style grad step + decode-vs-full consistency on CPU."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.models.config import ModelConfig

ALL = list(configs.ARCHS) + list(configs.PAPER_MODELS)
KEY = jax.random.PRNGKey(0)


def make_batch(cfg: ModelConfig, b=2, s=16, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(b, s)), dtype=jnp.int32
        ),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(b, s)), dtype=jnp.int32
        ),
    }
    if cfg.family == "encdec":
        batch["encoder_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_len, cfg.d_model)).astype(
                np.float32
            )
        )
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.vision_tokens, cfg.d_model)).astype(
                np.float32
            )
        )
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (3, b, s)
        )
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ALL)
def test_forward_and_grad_step(arch):
    cfg = configs.get_smoke_config(arch)
    params, state = transformer.init(KEY, cfg)
    batch = make_batch(cfg)
    logits, new_state, aux = transformer.forward(
        params, state, batch, cfg, train=True
    )
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN in logits"

    (loss, (st, metrics)), grads = jax.value_and_grad(
        transformer.loss_fn, has_aux=True
    )(params, state, batch, cfg, train=True)
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}: NaN grad"
    # at least one non-zero gradient in every top-level group
    total = sum(float(jnp.abs(g).sum()) for g in flat)
    assert total > 0


@pytest.mark.parametrize(
    "arch",
    ["yi-9b", "h2o-danube-3-4b", "zamba2-2.7b", "mixtral-8x7b",
     "mamba2-1.3b", "whisper-small", "qwen2-vl-72b", "qwen2-1.5b"],
)
@pytest.mark.slow
def test_decode_matches_full_forward(arch):
    """Stepwise decode through the cache must reproduce the causal forward."""
    cfg = configs.get_smoke_config(arch)
    if cfg.objective != "clm":
        pytest.skip("decode is causal-LM only")
    if cfg.num_experts:
        # capacity drops depend on the token population (full batch vs one
        # token at a time) — lift the capacity so none drop for this check
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    b, s = 2, 12
    params, state = transformer.init(KEY, cfg)
    batch = make_batch(cfg, b=b, s=s)
    if cfg.vision_tokens:
        # decode path has no vision stream: drop it for consistency check
        cfg = dataclasses.replace(cfg, vision_tokens=0)
        batch.pop("vision_embeds")
        batch.pop("positions", None)
    logits_full, _, _ = transformer.forward(params, state, batch, cfg)

    cache = transformer.init_cache(cfg, b, max_len=s)
    if cfg.family == "encdec":
        # decode needs the encoder cross-KV: use prefill for the first token
        logits_pf, cache = transformer.prefill(
            params, state,
            {"tokens": batch["tokens"][:, :1],
             "encoder_embeds": batch["encoder_embeds"]},
            cfg, max_len=s,
        )
        outs = [logits_pf[:, :1]]
        start = 1
    else:
        outs = []
        start = 0
    for t in range(start, s):
        logits_t, cache = transformer.decode_step(
            params, state, batch["tokens"][:, t : t + 1], t, cache, cfg
        )
        outs.append(logits_t)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec),
        np.asarray(logits_full),
        rtol=2e-3, atol=2e-3,
    )


@pytest.mark.parametrize(
    "arch", ["yi-9b", "h2o-danube-3-4b", "mamba2-1.3b", "mixtral-8x7b",
             "zamba2-2.7b"]
)
def test_prefill_then_decode(arch):
    """prefill(prompt) + decode(tail) == full forward on the whole sequence."""
    cfg = configs.get_smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    b, s, split = 2, 12, 8
    params, state = transformer.init(KEY, cfg)
    batch = make_batch(cfg, b=b, s=s)
    logits_full, _, _ = transformer.forward(params, state, batch, cfg)

    logits_pf, cache = transformer.prefill(
        params, state, {"tokens": batch["tokens"][:, :split]}, cfg, max_len=s
    )
    np.testing.assert_allclose(
        np.asarray(logits_pf),
        np.asarray(logits_full[:, :split]),
        rtol=2e-3, atol=2e-3,
    )
    outs = []
    for t in range(split, s):
        logits_t, cache = transformer.decode_step(
            params, state, batch["tokens"][:, t : t + 1], t, cache, cfg
        )
        outs.append(logits_t)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec),
        np.asarray(logits_full[:, split:]),
        rtol=2e-3, atol=2e-3,
    )


def test_full_configs_match_assignment():
    """Pin the exact assigned hyperparameters."""
    spec = {
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "mamba2-1.3b": (48, 2048, None, None, 0, 50280),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = configs.get_config(arch)
        assert cfg.num_layers == L and cfg.d_model == d
        assert cfg.d_ff == ff and cfg.vocab_size == v
        if h is not None:
            assert cfg.num_heads == h and cfg.num_kv_heads == kv
    assert configs.get_config("zamba2-2.7b").ssm_state == 64
    assert configs.get_config("mamba2-1.3b").ssm_state == 128
    assert configs.get_config("phi3.5-moe-42b-a6.6b").num_experts == 16
    assert configs.get_config("mixtral-8x7b").num_experts == 8


def test_moe_param_counts_plausible():
    """phi3.5: ~42B total / ~6.6B active; mixtral: ~47B / ~13B."""
    phi = configs.get_config("phi3.5-moe-42b-a6.6b")
    assert 38e9 < phi.param_count() < 46e9, phi.param_count()
    assert 5.5e9 < phi.active_param_count() < 8e9
    mix = configs.get_config("mixtral-8x7b")
    assert 44e9 < mix.param_count() < 50e9, mix.param_count()
    assert 11e9 < mix.active_param_count() < 15e9


@pytest.mark.slow
def test_lram_insertion_into_assigned_arch():
    cfg = configs.with_lram(configs.get_smoke_config("yi-9b"), 16)
    assert cfg.lram_layers and cfg.lram is not None
    params, state = transformer.init(KEY, cfg)
    batch = make_batch(cfg)
    logits, _, _ = transformer.forward(params, state, batch, cfg, train=True)
    assert bool(jnp.isfinite(logits).all())
    # memory values actually receive gradient
    g, _ = jax.grad(transformer.loss_fn, has_aux=True)(
        params, state, batch, cfg, train=True
    )
    seg = [k for k in g["segments"] if "memffn" in g["segments"][k]][0]
    vals = g["segments"][seg]["memffn"]["lram"]["values"]
    assert float(jnp.abs(vals).sum()) > 0
