"""Attention-layer unit tests: RoPE/M-RoPE, masks, GQA, cache mechanics."""


import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import attention, transformer
from repro.models.config import ModelConfig


def test_rope_preserves_norm_and_relative_phase(rng):
    x = jnp.asarray(rng.normal(size=(2, 6, 4, 32)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(6), (2, 6))
    y = attention.apply_rope(x, pos, 1e4)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # dot products depend only on relative positions
    q = jnp.asarray(rng.normal(size=(1, 8, 1, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 8, 1, 32)).astype(np.float32))
    p0 = jnp.broadcast_to(jnp.arange(8), (1, 8))
    qr0, kr0 = attention.apply_rope(q, p0, 1e4), attention.apply_rope(k, p0, 1e4)
    qr5, kr5 = attention.apply_rope(q, p0 + 5, 1e4), attention.apply_rope(
        k, p0 + 5, 1e4)
    s0 = np.einsum("bshd,bthd->bst", np.asarray(qr0), np.asarray(kr0))
    s5 = np.einsum("bshd,bthd->bst", np.asarray(qr5), np.asarray(kr5))
    np.testing.assert_allclose(s0, s5, rtol=1e-4, atol=1e-4)


def test_mrope_equals_rope_for_uniform_positions(rng):
    """Text tokens have t=h=w: M-RoPE must coincide with plain RoPE."""
    x = jnp.asarray(rng.normal(size=(2, 5, 2, 48)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(5), (2, 5))
    pos3 = jnp.broadcast_to(pos, (3, 2, 5))
    y1 = attention.apply_rope(x, pos, 1e4)
    y2 = attention.apply_mrope(x, pos3, 1e4, (8, 8, 8))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_swa_mask_limits_receptive_field():
    m = attention._band_mask(8, 8, causal=True, window=3)
    assert m[5, 5] and m[5, 3] and not m[5, 2]  # window of 3
    assert not m[3, 4]  # causal


def test_gqa_matches_mha_when_kv_repeated(rng):
    """GQA with repeated kv == MHA with those heads duplicated."""
    q = jnp.asarray(rng.normal(size=(1, 6, 4, 8)).astype(np.float32))
    k2 = jnp.asarray(rng.normal(size=(1, 6, 2, 8)).astype(np.float32))
    v2 = jnp.asarray(rng.normal(size=(1, 6, 2, 8)).astype(np.float32))
    out_gqa = attention.dense_attention(q, k2, v2, causal=True)
    k4 = jnp.repeat(k2, 2, axis=2)
    v4 = jnp.repeat(v2, 2, axis=2)
    out_mha = attention.dense_attention(q, k4, v4, causal=True)
    np.testing.assert_allclose(
        np.asarray(out_gqa), np.asarray(out_mha), atol=1e-5
    )


def test_layer_plan_structures():
    cfg = configs.get_config("yi-9b")
    assert transformer.layer_plan(cfg) == [("run", 48)]
    cfg = configs.with_lram(cfg, 20)
    plan = transformer.layer_plan(cfg)
    assert plan == [("run", 24), ("memory", 24, "lram"), ("run", 23)]
    z = configs.get_config("zamba2-2.7b")
    assert transformer.layer_plan(z) == [("hybrid", 9)]


def test_cache_shapes_swa_window_caps_length():
    cfg = configs.get_config("mixtral-8x7b")
    shapes = transformer.cache_shapes(cfg, batch=2, max_len=32768)
    (shape, _dtype) = shapes["seg0"]["k"]
    assert shape[2] == cfg.window  # ring buffer, not 32768
    yi = configs.get_config("yi-9b")
    shapes = transformer.cache_shapes(yi, batch=2, max_len=32768)
    assert shapes["seg0"]["k"][0][2] == 32768


def test_skip_reasons():
    from repro.configs import shapes as shapes_lib

    assert shapes_lib.skip_reason(configs.get_config("yi-9b"), "long_500k")
    assert shapes_lib.skip_reason(
        configs.get_config("mixtral-8x7b"), "long_500k") is None  # SWA
    assert shapes_lib.skip_reason(
        configs.get_config("mamba2-1.3b"), "long_500k") is None
    assert shapes_lib.skip_reason(
        configs.get_config("yi-9b"), "train_4k") is None


def test_with_lram_paper_block_shape():
    cfg = configs.with_lram(configs.get_config("yi-9b"), 20)
    assert cfg.lram.in_dim == cfg.d_model            # w
    assert cfg.lram.out_dim == 4 * cfg.d_model       # 4w
    assert cfg.lram.m == 64 and cfg.lram.heads == cfg.d_model // 16
