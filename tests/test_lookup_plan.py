"""The lookup-backend registry: the full placement × storage × kernel plan
matrix is numerically equivalent to the dense fp32 reference (eager + jit +
grad; quantized cells within `repro.quant.max_abs_error_bound`), impossible
cells raise `LookupPlanError` at resolve time, the legacy callable-hook
protocol is gone (clear error, not a silent shim), and sharded-tiered
stores train / checkpoint / serve like their single-range twins."""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess
from repro import memstore, nn, quant
from repro.checkpoint import CheckpointManager
from repro.core import lookup, lram
from repro.distributed import context as _ctx
from repro.distributed.sharded_lram import ShardedTieredStore
from repro.memstore import TieredSpec, TieredValueStore

KEY = jax.random.PRNGKey(0)
KW = dict(log2_locations=16, m=8, heads=2, query_norm="rms")

PLACEMENTS = ("dense", "tiered", "sharded", "sharded-tiered")
STORAGES = ("fp32", "int8", "fp8")
KERNELS = ("reference", "pallas")
MATRIX = [(p, s, k) for p in PLACEMENTS for s in STORAGES for k in KERNELS]


def make_cfg(placement, storage, kernel, **extra):
    kw = dict(KW, **extra)
    kw["table_quant"] = "none" if storage == "fp32" else storage
    kw["lookup_kernel"] = kernel
    if placement == "dense":
        impl = "reference"
    elif placement == "tiered":
        impl = "tiered"
        kw.setdefault("tiered", TieredSpec(shard_rows=4096, cache_slots=4))
    elif placement == "sharded":
        impl = "sharded"
    else:
        impl = "sharded-tiered"
        kw.setdefault("tiered", TieredSpec(shard_rows=2048, cache_slots=2))
        kw.setdefault("model_shards", 4)
    return lram.LRAMConfig(interp_impl=impl, **kw)


@pytest.fixture(scope="module")
def model_mesh():
    """A 1-device mesh with a 'model' axis: enough to resolve and run the
    sharded placements in-process (the 8-fake-device equivalence lives in
    the slow subprocess tests)."""
    return jax.make_mesh((1,), ("model",))


@pytest.fixture(scope="module")
def reference():
    """Dense fp32 reference layer + per-storage twins (same RNG draw)."""
    cfg = lram.LRAMConfig(**KW)
    params, state = lram.lram_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 3, cfg.in_dim))
    outs = {}
    grads = {}
    for storage in STORAGES:
        c = make_cfg("dense", storage, "reference")
        p, s = lram.lram_init(KEY, c)
        outs[storage] = np.asarray(lram.lram_apply(p, s, x, c)[0])
        grads[storage] = np.asarray(jax.grad(
            lambda xx: jnp.sum(lram.lram_apply(p, s, xx, c)[0] ** 2)
        )(x))
    return {"cfg": cfg, "params": params, "state": state, "x": x,
            "twin_out": outs, "twin_grad": grads}


# ---------------------------------------------------------------------------
# the plan matrix: every supported cell == the reference, eager + jit + grad
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("placement,storage,kernel", MATRIX)
def test_plan_matrix_matches_reference(placement, storage, kernel,
                                       reference, model_mesh):
    """Each cell reproduces the same-storage dense reference twin exactly
    (identical init rounding) and the fp32 reference within the documented
    quantization bound, under eager, jit, and grad-of-input."""
    cfg = make_cfg(placement, storage, kernel)
    x = reference["x"]
    y_twin = reference["twin_out"][storage]
    g_twin = reference["twin_grad"][storage]
    if placement == "sharded":
        _ctx.set_mesh(model_mesh)
    try:
        plan = lookup.resolve(cfg)
        assert plan.cell == (placement, storage, kernel)
        params, state = lram.lram_init(KEY, cfg)
        y = lram.lram_apply(params, state, x, cfg)[0]
        y_jit = jax.jit(
            lambda xx: lram.lram_apply(params, state, xx, cfg)[0]
        )(x)
        g = jax.grad(
            lambda xx: jnp.sum(lram.lram_apply(params, state, xx, cfg)[0]
                               ** 2)
        )(x)
    finally:
        _ctx.set_mesh(None)
    np.testing.assert_allclose(np.asarray(y), y_twin, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_jit), y_twin, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g), g_twin, atol=1e-4, rtol=1e-4)
    # sanity vs the fp32 twin (the hard bound is asserted at interp level
    # in test_plan_matrix_interp_error_bound)
    np.testing.assert_allclose(np.asarray(y), reference["twin_out"]["fp32"],
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("placement,storage,kernel", MATRIX)
def test_plan_matrix_interp_error_bound(placement, storage, kernel,
                                        model_mesh, rng):
    """plan.interp on a shared table draw stays within
    `quant.max_abs_error_bound` of the fp32 gather (exact for fp32)."""
    cfg = make_cfg(placement, storage, kernel)
    values = rng.normal(size=(2**16, 8)).astype(np.float32) * 0.02
    idx = jnp.asarray(rng.integers(0, 2**16, size=(16, 32)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
    want = np.einsum("...k,...km->...m", np.asarray(w), values[np.asarray(idx)])
    if placement == "sharded":
        _ctx.set_mesh(model_mesh)
    try:
        plan = lookup.resolve(cfg)
        table = plan.build_table(jnp.asarray(values))
        got = np.asarray(plan.interp(table, idx, w))
    finally:
        _ctx.set_mesh(None)
    if storage == "fp32":
        np.testing.assert_allclose(got, want, atol=1e-5)
    else:
        _, scale = quant.quantize_rows_np(values, storage)
        bound = quant.max_abs_error_bound(scale, np.asarray(w), storage)
        assert np.abs(got - want).max() <= bound + 1e-6


# ---------------------------------------------------------------------------
# impossible cells fail at resolve time, not inside apply
# ---------------------------------------------------------------------------

def test_unknown_impl_raises_plan_error():
    with pytest.raises(lookup.LookupPlanError, match="unknown interp_impl"):
        lookup.resolve(lram.LRAMConfig(**KW, interp_impl="bogus"))


def test_unknown_kernel_raises_plan_error():
    with pytest.raises(lookup.LookupPlanError, match="unknown kernel"):
        lookup.resolve(lram.LRAMConfig(**KW, lookup_kernel="cuda"))


def test_sharded_without_mesh_raises_plan_error():
    assert _ctx.get_mesh() is None
    with pytest.raises(lookup.LookupPlanError, match="mesh"):
        lookup.resolve(lram.LRAMConfig(**KW, interp_impl="sharded"))


def test_sharded_tiered_indivisible_ranges_raise():
    with pytest.raises(lookup.LookupPlanError, match="not divisible"):
        lookup.resolve(lram.LRAMConfig(
            **KW, interp_impl="sharded-tiered", model_shards=3,
        ))
    with pytest.raises(lookup.LookupPlanError, match="shard_rows"):
        lookup.resolve(lram.LRAMConfig(
            **KW, interp_impl="sharded-tiered", model_shards=4,
            tiered=TieredSpec(shard_rows=32768, cache_slots=1),
        ))


def test_quant_conflict_raises_plan_error():
    with pytest.raises(lookup.LookupPlanError, match="conflicts"):
        lookup.resolve(lram.LRAMConfig(
            **KW, interp_impl="tiered", table_quant="int8",
            tiered=TieredSpec(quant="fp8"),
        ))


def test_placement_table_mismatch_raises_plan_error():
    """Init dense, apply tiered: the plan rejects the mismatched table with
    a clear error instead of crashing inside the gather."""
    cfg = lram.LRAMConfig(**KW)
    params, state = lram.lram_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, cfg.in_dim))
    with pytest.raises(lookup.LookupPlanError, match="TieredValueStore"):
        lram.lram_apply(params, state, x, cfg, interp_impl="tiered")


def test_storage_table_mismatch_raises_plan_error():
    """A quantized table under an fp32 plan (and vice versa) is a clear
    LookupPlanError, not a crash deep inside the gather."""
    cfg_q = lram.LRAMConfig(**KW, table_quant="int8")
    params_q, state_q = lram.lram_init(KEY, cfg_q)
    x = jax.random.normal(KEY, (2, cfg_q.in_dim))
    cfg_fp = lram.LRAMConfig(**KW)
    with pytest.raises(lookup.LookupPlanError, match="QuantizedTable"):
        lram.lram_apply(params_q, state_q, x, cfg_fp)
    params_fp, state_fp = lram.lram_init(KEY, cfg_fp)
    with pytest.raises(lookup.LookupPlanError, match="QuantizedTable"):
        lram.lram_apply(params_fp, state_fp, x, cfg_q)


# ---------------------------------------------------------------------------
# legacy callable hooks: removed, with a clear error
# ---------------------------------------------------------------------------

def test_callable_hook_protocol_removed(reference):
    """The retired hook protocol fails loudly at resolve time — pointing
    at the registry — instead of silently bypassing the plan."""
    cfg, x = reference["cfg"], reference["x"]
    with pytest.raises(lookup.LookupPlanError, match="removed"):
        lram.lram_apply(reference["params"], reference["state"], x,
                        cfg, interp_impl=lram.gather_interp)
    assert not hasattr(lookup, "plan_from_callable")


# ---------------------------------------------------------------------------
# capability flags (what the serve engine / trainer / checkpoint read)
# ---------------------------------------------------------------------------

def test_plan_capabilities(model_mesh):
    dense = lookup.resolve(lram.LRAMConfig(**KW))
    assert not dense.supports_prefetch
    assert dense.table_update == "autodiff"
    assert dense.checkpoint_layout == "dense"
    assert dense.supports_growth and not dense.row_stats
    assert dense.table_rows_axis is None

    frozen = lookup.resolve(lram.LRAMConfig(**KW, table_quant="int8"))
    assert frozen.table_update == "frozen"
    assert frozen.supports_growth

    tiered = lookup.resolve(make_cfg("tiered", "int8", "reference"))
    assert tiered.supports_prefetch
    assert tiered.table_update == "writeback"
    assert tiered.checkpoint_layout == "shards"
    assert tiered.supports_growth and tiered.row_stats
    assert tiered.build_empty is not None

    st = lookup.resolve(make_cfg("sharded-tiered", "fp32", "reference"))
    assert st.supports_prefetch and st.table_update == "writeback"
    assert st.supports_growth and st.row_stats
    assert st.build_empty is not None

    _ctx.set_mesh(model_mesh)
    try:
        sharded = lookup.resolve(lram.LRAMConfig(**KW, interp_impl="sharded"))
    finally:
        _ctx.set_mesh(None)
    assert sharded.requires_mesh and not sharded.supports_prefetch
    # mesh-sharded dense tables reshard by relaunch, not live growth; the
    # plan emits its own pspec row axis instead of a sharding-rule regex
    assert not sharded.supports_growth
    assert sharded.table_rows_axis == "model"


@pytest.mark.slow
def test_sharded_pallas_and_quant_cells_on_real_mesh():
    """The previously-impossible sharded × pallas and sharded × int8 cells
    on an actual 8-fake-device mesh: the plan resolves, shard_maps the
    table over 4 model shards, and matches the dense fp32 reference
    (within the quant bound for int8), jit + grad included."""
    run_in_subprocess(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import quant
        from repro.core import lookup, lram
        from repro.distributed import context as _ctx

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        _ctx.set_mesh(mesh)
        KEY = jax.random.PRNGKey(0)
        kw = dict(log2_locations=16, m=8, heads=2, query_norm="rms")
        cfg_ref = lram.LRAMConfig(**kw)
        p_ref, s_ref = lram.lram_init(KEY, cfg_ref)
        x = jax.random.normal(KEY, (4, 3, cfg_ref.in_dim))
        y_ref, _ = lram.lram_apply(p_ref, s_ref, x, cfg_ref)

        for storage, kernel in (("none", "pallas"), ("int8", "reference"),
                                ("int8", "pallas")):
            cfg = lram.LRAMConfig(**kw, interp_impl="sharded",
                                  table_quant=storage, lookup_kernel=kernel)
            plan = lookup.resolve(cfg)
            assert plan.requires_mesh
            p, s = lram.lram_init(KEY, cfg)
            y, _ = lram.lram_apply(p, s, x, cfg)
            yj = jax.jit(lambda xx: lram.lram_apply(p, s, xx, cfg)[0])(x)
            g = jax.grad(lambda xx: jnp.sum(
                lram.lram_apply(p, s, xx, cfg)[0] ** 2))(x)
            assert bool(jnp.isfinite(g).all())
            np.testing.assert_allclose(np.asarray(y), np.asarray(yj),
                                       atol=1e-5)
            if storage == "none":
                np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                           atol=1e-5)
            else:
                assert np.abs(np.asarray(y) - np.asarray(y_ref)).max() < 2e-2
            print("cell", plan.cell, "OK")
    """), devices=8)


# ---------------------------------------------------------------------------
# memffn RNG decorrelation (the k2-never-used bug)
# ---------------------------------------------------------------------------

def test_memffn_init_keys_decorrelated():
    """wi must be seeded by its own split (k2), not share k1 with the
    memory table — the old correlated init is explicitly absent."""
    width = 64
    cfg = lram.memffn_config(width, 16, query_norm="rms")
    key = jax.random.PRNGKey(7)
    params, _ = lram.memffn_init(key, width, cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    np.testing.assert_array_equal(
        np.asarray(params["wi"]["kernel"]),
        np.asarray(nn.dense_init(k2, width, width)["kernel"]),
    )
    assert not np.allclose(
        np.asarray(params["wi"]["kernel"]),
        np.asarray(nn.dense_init(k1, width, width)["kernel"]),
    )
    np.testing.assert_array_equal(
        np.asarray(params["wo"]["kernel"]),
        np.asarray(nn.dense_init(k3, 4 * width, width)["kernel"]),
    )


# ---------------------------------------------------------------------------
# sharded-tiered: training write-back, checkpoint, store discovery
# ---------------------------------------------------------------------------

def test_sharded_tiered_writeback_routes_to_owning_ranges(rng):
    dense = rng.normal(size=(4096, 8)).astype(np.float32)
    store = ShardedTieredStore.from_dense(
        dense, TieredSpec(shard_rows=256, cache_slots=2), num_ranges=4
    )
    store.writeback_lr = 0.1
    assert store.parts[2].writeback_lr == 0.1
    idx = rng.integers(0, 4096, size=(16, 8)).astype(np.int32)
    w = jnp.asarray(rng.normal(size=idx.shape).astype(np.float32))

    def loss(w_):
        return jnp.sum(
            memstore.tiered_interp(store, jnp.asarray(idx), w_) ** 2
        )

    dw = jax.grad(loss)(w)
    assert bool(jnp.isfinite(dw).all())
    after = store.to_dense()
    touched = np.zeros(4096, bool)
    touched[idx.reshape(-1)] = True
    assert not np.allclose(after[touched], dense[touched])
    np.testing.assert_array_equal(after[~touched], dense[~touched])


def test_sharded_tiered_stats_exclude_bucket_padding(rng):
    """The power-of-two padding in the routed gather is weight-0 filler —
    it must not inflate hits/misses/uncached (hit_rate feeds the table9
    rows and the serve report)."""
    dense = rng.normal(size=(4096, 8)).astype(np.float32)
    store = ShardedTieredStore.from_dense(
        dense, TieredSpec(shard_rows=256, cache_slots=4), num_ranges=2
    )
    idx = rng.integers(0, 4096, size=(11, 12)).astype(np.int32)  # 132 elems
    w = rng.normal(size=idx.shape).astype(np.float32)
    store.gather(idx, w)
    s = store.stats
    assert s["hits"] + s["misses"] + s["uncached"] == idx.size


def test_sharded_tiered_checkpoint_cross_restores(rng, tmp_path):
    """A sharded-tiered checkpoint streams global shard ids, so it restores
    bit-exact into a fresh sharded-tiered store, a plain tiered store of
    the same total layout, and a dense proto."""
    dense = rng.normal(size=(2048, 8)).astype(np.float32)
    spec = TieredSpec(shard_rows=256, cache_slots=2)
    store = ShardedTieredStore.from_dense(dense, spec, num_ranges=2)
    store.writeback_lr = 0.5
    idx = rng.integers(0, 2048, size=(64,)).astype(np.int32)
    store.gather_rows_host(idx)
    store.apply_writeback(idx, rng.normal(size=(64, 8)).astype(np.float32))
    assert any(part._dirty for part in store.parts)

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"values": store})
    expected = store.to_dense()

    fresh = ShardedTieredStore(2048, 8, spec, num_ranges=2)
    step, _ = mgr.restore({"values": fresh})
    assert step == 1
    np.testing.assert_array_equal(fresh.to_dense(), expected)

    tiered = TieredValueStore(2048, 8, spec)
    mgr.restore({"values": tiered})
    np.testing.assert_array_equal(tiered.to_dense(), expected)

    _, r = mgr.restore({"values": jnp.zeros((2048, 8))})
    np.testing.assert_allclose(np.asarray(r["values"]), expected, atol=1e-7)

    # and the reverse: a plain tiered checkpoint into a sharded-tiered store
    mgr2 = CheckpointManager(str(tmp_path / "t"))
    mgr2.save(1, {"values": tiered})
    fresh2 = ShardedTieredStore(2048, 8, spec, num_ranges=2)
    mgr2.restore({"values": fresh2})
    np.testing.assert_array_equal(fresh2.to_dense(), expected)


def test_find_stores_covers_sharded_tiered(rng):
    store = ShardedTieredStore.from_dense(
        rng.normal(size=(1024, 8)).astype(np.float32),
        TieredSpec(shard_rows=128, cache_slots=2), num_ranges=2,
    )
    tree = {"a": jnp.ones((2,)), "values": store}
    assert lookup.find_stores(tree) == [("values", store)]
    assert memstore.find_stores(tree) == [("values", store)]
    # leafless pytree node: invisible to tree maps
    mapped = jax.tree.map(lambda x: x * 2, tree)
    assert mapped["values"] is store


def test_sharded_tiered_config_and_engine_discovery():
    """The lram-sharded-tiered arch resolves through the registry, and the
    serve engine discovers its prefetch handles via plan capabilities."""
    from repro import configs
    from repro.models import transformer
    from repro.serving import EngineConfig, ServeEngine, synthetic_trace

    cfg = configs.get_smoke_config("lram-sharded-tiered")
    plan = lookup.resolve(cfg.lram)
    assert plan.placement == "sharded-tiered"
    assert plan.supports_prefetch

    params, state = transformer.init(jax.random.PRNGKey(0), cfg)
    found = lookup.find_stores(params)
    assert len(found) == 1
    store = found[0][1]
    assert isinstance(store, ShardedTieredStore)
    assert store.num_ranges == 2

    engine = ServeEngine(params, state, cfg,
                         EngineConfig(slots=2, max_len=24))
    assert [s for _, s in engine.stores] == [store]
    trace = synthetic_trace(np.random.default_rng(0), 3,
                            vocab_size=cfg.vocab_size, max_prompt=8,
                            max_gen=4)
    report = engine.run(trace)
    assert report.generated_tokens > 0
    assert report.cache is not None and "hit_rate" in report.cache
    assert all(r.cache_hit_rate is not None for r in report.requests)
