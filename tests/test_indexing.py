"""The O(1) bijection between lattice points on the torus and [0, N)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import indexing, lattice


def test_choose_torus_counts():
    for log2 in (16, 18, 20, 24):
        spec = indexing.choose_torus(log2)
        assert spec.num_locations == 2**log2
        assert all(k >= 8 and k % 4 == 0 for k in spec.K)


def test_choose_torus_too_small():
    with pytest.raises(ValueError):
        indexing.choose_torus(15)


def test_bad_wrap_lengths():
    with pytest.raises(ValueError):
        indexing.TorusSpec((4,) * 8)  # wrap < kernel diameter
    with pytest.raises(ValueError):
        indexing.TorusSpec((10,) * 8)  # not divisible by 4


@pytest.mark.slow
@settings(deadline=None, max_examples=50)
@given(st.integers(0, 2**18 - 1))
def test_roundtrip_random_indices(idx):
    spec = indexing.choose_torus(18)
    pts = indexing.decode_index(np.array([idx]), spec)
    assert lattice.is_lattice_point(pts).all()
    assert np.all(pts >= 0) and np.all(pts < np.array(spec.K))
    back = np.asarray(indexing.encode_points(jnp.asarray(pts), spec))
    assert back[0] == idx


def test_roundtrip_dense_block():
    spec = indexing.choose_torus(16)
    idx = np.arange(2**16)
    pts = indexing.decode_index(idx, spec)
    assert lattice.is_lattice_point(pts).all()
    # all distinct lattice points
    assert len({tuple(p) for p in pts}) == 2**16
    back = np.asarray(indexing.encode_points(jnp.asarray(pts), spec))
    np.testing.assert_array_equal(back, idx)


def test_wrap_invariance(rng):
    spec = indexing.choose_torus(18)
    idx = rng.integers(0, 2**18, size=200)
    pts = indexing.decode_index(idx, spec)
    shifts = rng.integers(-3, 4, size=(200, 8)) * np.array(spec.K)
    back = np.asarray(indexing.encode_points(jnp.asarray(pts + shifts), spec))
    np.testing.assert_array_equal(back, idx)


def test_negative_coordinates(rng):
    """Neighbors straight from the decoder can have negative coords."""
    spec = indexing.choose_torus(16)
    q = rng.uniform(-4, 4, size=(500, 8)).astype(np.float32)
    nb, w = lattice.neighbors_and_weights(jnp.asarray(q))
    idx = np.asarray(indexing.encode_points(nb, spec))
    assert idx.min() >= 0 and idx.max() < spec.num_locations


def test_distinct_neighbors_get_distinct_indices(rng):
    """Within one query's kernel support, the 232 candidates never collide
    on the torus (wrap length >= kernel diameter)."""
    spec = indexing.choose_torus(16)  # smallest torus: K=(8,)*8
    q = rng.uniform(0, 8, size=(50, 8)).astype(np.float32)
    nb, w = map(np.asarray, lattice.neighbors_and_weights(jnp.asarray(q)))
    idx = np.asarray(indexing.encode_points(jnp.asarray(nb), spec))
    for i in range(50):
        live = idx[i][w[i] > 0]
        assert len(set(live.tolist())) == len(live)
