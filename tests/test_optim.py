"""Optimizer: Adam correctness vs numpy reference, param groups, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim


def numpy_adam(params, grads, steps, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = {k: np.zeros_like(v) for k, v in params.items()}
    v_ = {k: np.zeros_like(v) for k, v in params.items()}
    p = {k: v.copy() for k, v in params.items()}
    for t in range(1, steps + 1):
        for k in p:
            g = grads[k]
            m[k] = b1 * m[k] + (1 - b1) * g
            v_[k] = b2 * v_[k] + (1 - b2) * g * g
            mhat = m[k] / (1 - b1**t)
            vhat = v_[k] / (1 - b2**t)
            p[k] -= lr * mhat / (np.sqrt(vhat) + eps)
    return p


def test_adam_matches_numpy_reference(rng):
    params = {"a": rng.normal(size=(8, 4)).astype(np.float32),
              "b": rng.normal(size=(16,)).astype(np.float32)}
    grads = {"a": rng.normal(size=(8, 4)).astype(np.float32),
             "b": rng.normal(size=(16,)).astype(np.float32)}
    cfg = optim.OptimConfig(lr=1e-2, grad_clip=0.0, memory_lr_mult=1.0)
    jp = jax.tree.map(jnp.asarray, params)
    st = optim.adam_init(jp)
    for _ in range(5):
        jp, st, _ = optim.adam_update(
            jax.tree.map(jnp.asarray, grads), st, jp, cfg
        )
    want = numpy_adam(params, grads, 5, 1e-2)
    for k in params:
        np.testing.assert_allclose(np.asarray(jp[k]), want[k], rtol=1e-5,
                                   atol=1e-6)


def test_memory_param_group_gets_10x_lr(rng):
    params = {
        "dense": {"kernel": jnp.zeros((4, 4))},
        "lram": {"values": jnp.zeros((16, 4))},
    }
    grads = jax.tree.map(lambda p: jnp.ones_like(p), params)
    cfg = optim.OptimConfig(lr=1e-3, memory_lr_mult=10.0, grad_clip=0.0)
    st = optim.adam_init(params)
    new, _, _ = optim.adam_update(grads, st, params, cfg)
    # first Adam step moves by exactly lr * mult (mhat/sqrt(vhat) = 1)
    step_dense = float(jnp.abs(new["dense"]["kernel"]).mean())
    step_mem = float(jnp.abs(new["lram"]["values"]).mean())
    assert step_mem / step_dense == pytest.approx(10.0, rel=1e-3)


def test_grad_clipping():
    params = {"a": jnp.zeros((4,))}
    grads = {"a": jnp.full((4,), 100.0)}
    cfg = optim.OptimConfig(lr=1.0, grad_clip=1.0, memory_lr_mult=1.0)
    st = optim.adam_init(params)
    _, _, stats = optim.adam_update(grads, st, params, cfg)
    assert float(stats["grad_norm"]) == pytest.approx(200.0)


def test_schedules():
    cfg = optim.OptimConfig(lr=1.0, schedule="cosine", warmup_steps=10,
                            total_steps=100)
    lrs = [float(optim.schedule_lr(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup rises
    assert lrs[2] > lrs[3] > lrs[4]          # cosine decays
    assert lrs[4] == pytest.approx(0.0, abs=1e-6)


def test_int8_compression_error_feedback_preserves_sum(rng):
    """Error feedback: sum of transmitted grads converges to sum of true
    grads (residual stays bounded)."""
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32)) * 1e-3
    params = {"w": jnp.zeros((256,))}
    comp = optim.compression_init(params, "int8")
    total_sent = jnp.zeros((256,))
    for _ in range(20):
        sent, comp = optim.compress_gradients({"w": g}, comp)
        total_sent = total_sent + sent["w"]
    resid = np.abs(np.asarray(comp["residual"]["w"]))
    np.testing.assert_allclose(
        np.asarray(total_sent) + np.asarray(comp["residual"]["w"]),
        np.asarray(20 * g), rtol=1e-4, atol=1e-6,
    )
    assert resid.max() < float(jnp.abs(g).max())  # bounded residual


def test_topk_compression_sparsity(rng):
    g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    params = {"w": jnp.zeros((1000,))}
    comp = optim.compression_init(params, "topk", rho=0.05)
    sent, comp = optim.compress_gradients({"w": g}, comp)
    nnz = int((sent["w"] != 0).sum())
    assert nnz <= 60  # ~5% of 1000 (ties may add a few)
    # dense after enough rounds: residual keeps the rest
    assert float(jnp.abs(comp["residual"]["w"]).sum()) > 0


def test_compression_none_passthrough():
    params = {"w": jnp.ones((4,))}
    comp = optim.compression_init(params, "none")
    g = {"w": jnp.full((4,), 3.0)}
    sent, comp2 = optim.compress_gradients(g, comp)
    assert sent is g and comp2 is comp
