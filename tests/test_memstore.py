"""Tiered memory store: LRU eviction order, dense equivalence of the
miss->prefetch->hit paths (eager + jitted), write-back training, and
streaming checkpoint of a table with dirty shards."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypo import given, settings, st
from repro import memstore
from repro.checkpoint import CheckpointManager
from repro.core import lram
from repro.memstore import TieredSpec, TieredValueStore

KEY = jax.random.PRNGKey(0)


def make_store(rng, *, rows=4096, m=8, shard_rows=256, slots=4, **kw):
    dense = rng.normal(size=(rows, m)).astype(np.float32)
    spec = TieredSpec(shard_rows=shard_rows, cache_slots=slots, **kw)
    return dense, TieredValueStore.from_dense(dense, spec)


def dense_ref(dense, idx, w):
    return np.einsum("...k,...km->...m", w, dense[idx])


# ---------------------------------------------------------------------------
# Eviction policy
# ---------------------------------------------------------------------------

def _check_lru_against_model(seed, lookups=40, shards=16, slots=4):
    """Property: after any access sequence, the cache holds exactly the
    `slots` most-recently-touched distinct shards (LRU), matching an
    OrderedDict reference model."""
    rng = np.random.default_rng(seed)
    _, store = make_store(
        rng, rows=shards * 64, shard_rows=64, slots=slots
    )
    import collections
    model = collections.OrderedDict()
    for _ in range(lookups):
        # touch at most `slots` distinct shards so nothing overflows
        batch_shards = np.unique(
            rng.integers(0, shards, size=rng.integers(1, slots + 1))
        )
        idx = (batch_shards[:, None] * 64
               + rng.integers(0, 64, (len(batch_shards), 8))).reshape(-1)
        store.gather_rows_host(idx.astype(np.int32))
        for s in sorted(batch_shards.tolist()):
            model[s] = True
            model.move_to_end(s)
        while len(model) > slots:
            model.popitem(last=False)
        assert store.resident_shards() == list(model), (
            f"seed={seed}: cache order diverged from LRU model"
        )


@pytest.mark.parametrize("seed", range(8))
def test_lru_eviction_order_matches_model(seed):
    _check_lru_against_model(seed)


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 2**31 - 1))
def test_lru_eviction_order_property(seed):
    _check_lru_against_model(seed, lookups=20)


def test_pinned_shards_never_evicted_mid_batch(rng):
    """A batch spanning more shards than slots must stay exact: overflow
    rows are served from the host tier, never by evicting a pinned shard."""
    dense, store = make_store(rng, rows=4096, shard_rows=256, slots=2)
    idx = rng.integers(0, 4096, size=(32, 16)).astype(np.int32)  # 16 shards
    w = rng.normal(size=idx.shape).astype(np.float32)
    out = np.asarray(store.gather(idx, w))
    np.testing.assert_allclose(out, dense_ref(dense, idx, w), atol=1e-5)
    assert store.stats["uncached"] > 0
    assert len(store.resident_shards()) <= 2


# ---------------------------------------------------------------------------
# miss -> prefetch -> hit round trip, dense equivalence
# ---------------------------------------------------------------------------

def test_miss_prefetch_hit_round_trip(rng):
    dense, store = make_store(rng, slots=4)
    idx = (rng.integers(0, 4, size=(8, 32)) * 256
           + rng.integers(0, 256, (8, 32))).astype(np.int32)  # 4 shards
    w = rng.normal(size=idx.shape).astype(np.float32)

    out_miss = np.asarray(store.gather(idx, w))  # cold: all misses
    assert store.stats["hits"] == 0 and store.stats["misses"] > 0
    store.reset_stats()

    out_hit = np.asarray(store.gather(idx, w))   # warm: all hits
    assert store.hit_rate() == 1.0 and store.stats["misses"] == 0

    store._invalidate_cache()
    store.prefetch(idx)                           # explicit prefetch
    store.reset_stats()
    out_pref = np.asarray(store.gather(idx, w))
    assert store.hit_rate() == 1.0

    expected = dense_ref(dense, idx, w)
    for out in (out_miss, out_hit, out_pref):
        np.testing.assert_allclose(out, expected, atol=1e-5)


def test_lram_apply_tiered_matches_dense(rng):
    """interp_impl='tiered' == dense reference, cache <50% of shards,
    both eager and under jit (io_callback path)."""
    kw = dict(log2_locations=16, m=8, heads=4, query_norm="rms")
    dense_cfg = lram.LRAMConfig(**kw)
    tiered_cfg = lram.LRAMConfig(
        **kw, interp_impl="tiered",
        tiered=TieredSpec(shard_rows=4096, cache_slots=4),  # 4/16 resident
    )
    pd, sd = lram.lram_init(KEY, dense_cfg)
    pt, st_ = lram.lram_init(KEY, tiered_cfg)
    store = pt["values"]
    assert isinstance(store, TieredValueStore)
    x = jax.random.normal(KEY, (3, 5, dense_cfg.in_dim))

    yd, _ = lram.lram_apply(pd, sd, x, dense_cfg)
    yt, _ = lram.lram_apply(pt, st_, x, tiered_cfg)  # eager device-cache path
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yt), atol=1e-5)
    assert store.stats["lookups"] == 1

    yj = jax.jit(lambda xx: lram.lram_apply(pt, st_, xx, tiered_cfg)[0])(x)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yj), atol=1e-5)


def test_tiered_input_gradients_match_dense(rng):
    kw = dict(log2_locations=16, m=8, heads=4, query_norm="rms")
    dense_cfg = lram.LRAMConfig(**kw)
    tiered_cfg = lram.LRAMConfig(
        **kw, interp_impl="tiered",
        tiered=TieredSpec(shard_rows=4096, cache_slots=4),
    )
    pd, sd = lram.lram_init(KEY, dense_cfg)
    pt, st_ = lram.lram_init(KEY, tiered_cfg)
    x = jax.random.normal(KEY, (8, dense_cfg.in_dim))
    gd = jax.grad(
        lambda xx: jnp.sum(lram.lram_apply(pd, sd, xx, dense_cfg)[0] ** 2)
    )(x)
    gt = jax.grad(
        lambda xx: jnp.sum(lram.lram_apply(pt, st_, xx, tiered_cfg)[0] ** 2)
    )(x)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(gt), atol=1e-5)


def test_writeback_applies_sparse_sgd(rng):
    dense, store = make_store(rng, slots=4)
    store.writeback_lr = 0.1
    idx = rng.integers(0, 1024, size=(16, 8)).astype(np.int32)
    w = jnp.asarray(rng.normal(size=idx.shape).astype(np.float32))

    def loss(w_):
        return jnp.sum(memstore.tiered_interp(store, jnp.asarray(idx), w_) ** 2)

    dw = jax.grad(loss)(w)
    assert bool(jnp.isfinite(dw).all())
    assert store.stats["writebacks"] == 1 and store._dirty
    after = store.to_dense()
    touched = np.zeros(4096, bool)
    touched[idx.reshape(-1)] = True
    assert not np.allclose(after[touched], dense[touched])
    np.testing.assert_array_equal(after[~touched], dense[~touched])


def test_pallas_indirected_gather_matches(rng):
    dense, store = make_store(
        rng, rows=1024, shard_rows=128, slots=8, use_pallas=True
    )
    idx = rng.integers(0, 1024, size=(8, 16)).astype(np.int32)
    w = rng.normal(size=idx.shape).astype(np.float32)
    out = np.asarray(store.gather(idx, w))
    np.testing.assert_allclose(out, dense_ref(dense, idx, w), atol=1e-5)


def test_mmap_backing_round_trip(rng, tmp_path):
    dense = rng.normal(size=(1024, 8)).astype(np.float32)
    spec = TieredSpec(shard_rows=128, cache_slots=2, backing="mmap",
                      backing_dir=str(tmp_path))
    store = TieredValueStore.from_dense(dense, spec)
    idx = rng.integers(0, 1024, size=(4, 8)).astype(np.int32)
    w = rng.normal(size=idx.shape).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(store.gather(idx, w)), dense_ref(dense, idx, w), atol=1e-5
    )
    assert list(tmp_path.glob("*.npy"))


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_streams_dirty_tiered_table(rng, tmp_path):
    dense, store = make_store(rng, rows=2048, shard_rows=256, slots=3)
    store.writeback_lr = 0.5
    idx = rng.integers(0, 2048, size=(64,)).astype(np.int32)
    store.gather_rows_host(idx)
    store.apply_writeback(idx, rng.normal(size=(64, 8)).astype(np.float32))
    assert store._dirty, "test needs dirty cached shards"

    tree = {"params": {"values": store, "w": jnp.ones((3,))},
            "opt": {"mu": {"values": store}}}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, tree)
    expected = store.to_dense()

    # the shared store must be written once (tiered) + referenced (ref)
    import json, os
    man = json.load(open(os.path.join(
        str(tmp_path), "step_000000000005", "manifest.json")))
    kinds = sorted(v.get("kind", "array") for v in man["leaves"].values())
    assert kinds == ["array", "tiered", "tiered_ref"]

    fresh = TieredValueStore(2048, 8, TieredSpec(shard_rows=256,
                                                 cache_slots=3))
    tree2 = {"params": {"values": fresh, "w": jnp.zeros((3,))},
             "opt": {"mu": {"values": fresh}}}
    step, restored = mgr.restore(tree2)
    assert step == 5
    np.testing.assert_array_equal(fresh.to_dense(), expected)
    assert restored["params"]["values"] is fresh

    # tiered checkpoint restored into a dense proto materializes host-side
    tree3 = {"params": {"values": jnp.zeros((2048, 8)), "w": jnp.zeros((3,))},
             "opt": {"mu": {"values": jnp.zeros((2048, 8))}}}
    _, r3 = mgr.restore(tree3)
    np.testing.assert_allclose(np.asarray(r3["params"]["values"]), expected)


def test_corrupt_shard_falls_back_to_older_checkpoint(rng, tmp_path):
    dense, store = make_store(rng, rows=1024, shard_rows=128, slots=2)
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"values": store}
    mgr.save(1, tree)
    expected = store.to_dense()
    store.writeback_lr = 0.5
    idx = rng.integers(0, 1024, size=(32,)).astype(np.int32)
    store.gather_rows_host(idx)
    store.apply_writeback(idx, rng.normal(size=(32, 8)).astype(np.float32))
    mgr.save(2, tree)

    import os
    bad = os.path.join(str(tmp_path), "step_000000000002",
                       "values.npy.shards", "shard_000003.npy")
    with open(bad, "wb") as f:
        f.write(b"garbage")

    fresh = TieredValueStore(1024, 8, TieredSpec(shard_rows=128,
                                                 cache_slots=2))
    step, _ = mgr.restore({"values": fresh})
    assert step == 1  # newest shard set corrupt -> older checkpoint wins
    np.testing.assert_array_equal(fresh.to_dense(), expected)

    # every candidate corrupt AND the store already partially overwritten:
    # restore must raise, not silently hand back a half-loaded table
    bad1 = os.path.join(str(tmp_path), "step_000000000001",
                        "values.npy.shards", "shard_000003.npy")
    with open(bad1, "wb") as f:
        f.write(b"garbage")
    fresh2 = TieredValueStore(1024, 8, TieredSpec(shard_rows=128,
                                                  cache_slots=2))
    with pytest.raises(IOError):
        mgr.restore({"values": fresh2})


def test_store_is_invisible_to_tree_maps(rng):
    _, store = make_store(rng)
    tree = {"a": jnp.ones((2,)), "values": store}
    mapped = jax.tree.map(lambda x: x * 2, tree)
    assert mapped["values"] is store
    assert len(jax.tree.leaves(tree)) == 1
    assert memstore.find_stores(tree) == [("values", store)]


def test_smoke_config_table_exceeds_cache_budget():
    """The acceptance regime: N strictly larger than the device budget."""
    from repro import configs
    cfg = configs.get_smoke_config("lram-tiered")
    spec = cfg.lram.tiered
    table_rows = cfg.lram.num_locations
    cached_rows = spec.cache_slots * spec.shard_rows
    assert cached_rows < table_rows // 2
