"""Pin the lattice implementation to the paper's exact constants (§2.4-2.6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import lattice


# ---------------------------------------------------------------------------
# Structure of Lambda
# ---------------------------------------------------------------------------

def test_shell_sizes_match_e8_theta_series():
    shells = lattice.shell_vectors()
    nsq = (shells**2).sum(1)
    # E8 theta series: 240 vectors of (scaled) norm^2 8, 2160 of norm^2 16
    assert (nsq == 0).sum() == 1
    assert (nsq == 8).sum() == 240
    assert (nsq == 16).sum() == 2160
    assert lattice.is_lattice_point(shells).all()


def test_minimum_distance_and_radii():
    shells = lattice.shell_vectors()
    nsq = (shells**2).sum(1)
    assert nsq[nsq > 0].min() == 8  # min distance sqrt(8)
    assert lattice.PACKING_RADIUS == pytest.approx(np.sqrt(8) / 2)
    assert lattice.COVERING_RADIUS == 2.0


def test_fundamental_region_candidates_exactly_232():
    assert lattice.candidate_table().shape == (232, lattice.DIM)


def test_candidate_distance_gap_is_clean():
    """No shell point has d(p,F)^2 within 1e-3 of the cut — the count of 232
    is robust, not a numerical accident."""
    d2 = lattice.distance_sq_to_fundamental_region(
        lattice.shell_vectors().astype(np.float64)
    )
    near_cut = np.abs(d2 - lattice.RADIUS_SQ) < 1e-3
    assert np.all(np.abs(d2[near_cut] - lattice.RADIUS_SQ) < 1e-7)
    assert (d2 < 8 - 1e-3).sum() == 232


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def test_decode_returns_true_nearest_point(rng):
    q = rng.uniform(-20, 20, size=(500, 8)).astype(np.float32)
    c = np.asarray(lattice.decode(jnp.asarray(q)))
    assert lattice.is_lattice_point(c.astype(np.int64)).all()
    shells = lattice.shell_vectors()
    for i in range(0, 500, 7):
        pts = c[i].astype(np.int64) + shells
        d2 = ((pts - q[i]) ** 2).sum(1)
        dc = ((c[i] - q[i]) ** 2).sum()
        assert dc <= d2.min() + 1e-4


def test_decode_fixed_points():
    pts = np.array(
        [[0] * 8, [2, 2, 0, 0, 0, 0, 0, 0], [1] * 8, [4, 0, 0, 0, 0, 0, 0, 0],
         [3, 1, 1, 1, 1, 1, 1, -1]],
        dtype=np.float32,
    )
    assert lattice.is_lattice_point(pts.astype(np.int64)).all()
    out = np.asarray(lattice.decode(jnp.asarray(pts)))
    np.testing.assert_array_equal(out, pts)


@pytest.mark.slow
@settings(deadline=None, max_examples=30)
@given(st.lists(st.floats(-50, 50, width=32), min_size=8, max_size=8))
def test_decode_within_covering_radius(coords):
    q = jnp.asarray(np.array(coords, dtype=np.float32))
    c = lattice.decode(q)
    assert float(jnp.sum((q - c) ** 2)) <= lattice.COVERING_RADIUS**2 + 1e-3


# ---------------------------------------------------------------------------
# Canonicalization
# ---------------------------------------------------------------------------

def test_canonicalize_lands_in_F_and_is_isometric(rng):
    q = rng.uniform(-10, 10, size=(300, 8)).astype(np.float32)
    c = np.asarray(lattice.decode(jnp.asarray(q)))
    t = q - c
    z, perm, sgn = map(np.asarray, lattice.canonicalize(jnp.asarray(t)))
    assert np.all(np.diff(z[:, :7], axis=1) <= 1e-6)
    assert np.all(z[:, 6] >= np.abs(z[:, 7]) - 1e-6)
    assert np.all(z[:, 0] + z[:, 1] <= 2 + 1e-5)
    assert np.all(z.sum(1) <= 4 + 1e-5)
    # isometry: |z| is a permutation of |t|, and reconstruction is exact
    np.testing.assert_allclose(
        np.sort(np.abs(z), axis=1), np.sort(np.abs(t), axis=1), atol=1e-6
    )
    tp = np.take_along_axis(t, perm, axis=1)
    np.testing.assert_allclose(z, sgn * tp, atol=1e-6)
    # even number of sign flips
    assert np.all(np.prod(sgn, axis=1) > 0)


# ---------------------------------------------------------------------------
# Kernel support statistics (paper Table 1 + §2.5) — the paper's own numbers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mc_weights():
    rng = np.random.default_rng(42)
    q = rng.uniform(0, 16, size=(60_000, 8)).astype(np.float32)
    f = jax.jit(lattice.neighbors_and_weights)
    ws = []
    for i in range(0, len(q), 20_000):
        _, w = f(jnp.asarray(q[i : i + 20_000]))
        ws.append(np.asarray(w))
    return np.concatenate(ws)


def test_kernel_support_stats(mc_weights):
    counts = (mc_weights > 0).sum(1)
    # paper Table 1 (E8 column): min 45 (m.c.), avg 64.94, max 121
    assert counts.max() <= 121
    assert counts.min() >= 40
    assert abs(counts.mean() - lattice.MEAN_SUPPORT) < 0.5
    # analytic mean = V_8(sqrt 8)/det = pi^4*4096/24/256
    assert lattice.MEAN_SUPPORT == pytest.approx(64.9393, abs=1e-3)


def test_weight_bounds(mc_weights):
    s = mc_weights.sum(1)
    # paper §2.5: 0.851 <= w(x) <= 1
    assert s.min() >= lattice.WEIGHT_LOWER_BOUND - 1e-4
    assert s.max() <= 1.0 + 1e-5


def test_top32_weight_fraction(mc_weights):
    s = mc_weights.sum(1)
    top = np.sort(mc_weights, axis=1)[:, -32:].sum(1)
    frac = top / s
    # paper §2.6: top-32 carries >=90% always, ~99.5% on average
    assert frac.min() >= 0.90
    assert frac.mean() >= 0.99


def test_weight_is_one_at_lattice_points_and_deep_holes():
    pts = np.array(
        [[0] * 8, [2, 2, 0, 0, 0, 0, 0, 0], [1] * 8,  # lattice points
         [2, 0, 0, 0, 0, 0, 0, 0], [0, 2, 0, 0, 0, 0, 0, 0]],  # deep holes
        dtype=np.float32,
    )
    _, w = lattice.neighbors_and_weights(jnp.asarray(pts))
    np.testing.assert_allclose(np.asarray(w).sum(1), 1.0, atol=1e-5)


def test_deep_hole_support_is_16_equal_weights():
    """At a deep hole, exactly 16 points at distance 2 contribute 1/16 each."""
    dh = jnp.asarray(np.array([[2, 0, 0, 0, 0, 0, 0, 0]], dtype=np.float32))
    _, w = lattice.neighbors_and_weights(dh)
    w = np.asarray(w)[0]
    nz = w[w > 0]
    assert len(nz) == 16
    np.testing.assert_allclose(nz, 1.0 / 16.0, atol=1e-6)


# ---------------------------------------------------------------------------
# Completeness: candidate pipeline == brute force
# ---------------------------------------------------------------------------

def test_neighbor_enumeration_complete(rng):
    q = rng.uniform(-8, 24, size=(100, 8)).astype(np.float32)
    nb, w = map(np.asarray, lattice.neighbors_and_weights(jnp.asarray(q)))
    for i in range(100):
        oracle_pts, oracle_d2 = lattice.brute_force_neighbors(q[i])
        got = {
            tuple(p): wi
            for p, wi in zip(nb[i].astype(np.int64), w[i])
            if wi > 0
        }
        want = {
            tuple(p): float(lattice.kernel_from_sq(jnp.asarray(d)))
            for p, d in zip(oracle_pts, oracle_d2)
        }
        assert set(got) == set(want)
        for k in got:
            assert got[k] == pytest.approx(want[k], abs=1e-5)


def test_kernel_function_values():
    assert float(lattice.kernel_from_sq(jnp.asarray(0.0))) == 1.0
    assert float(lattice.kernel_from_sq(jnp.asarray(8.0))) == 0.0
    assert float(lattice.kernel_from_sq(jnp.asarray(12.0))) == 0.0
    assert float(lattice.kernel_from_sq(jnp.asarray(4.0))) == pytest.approx(
        0.5**4
    )
