"""Quantized value tables: round-trip error bounds, agreement of every
lookup implementation (reference | pallas | tiered | sharded) with the fp32
reference under jit and grad, unbiasedness of the stochastic-rounding
write-back, and quantized checkpoint save/restore."""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess
from repro import quant
from repro.checkpoint import CheckpointManager
from repro.core import lram
from repro.memstore import TieredSpec, TieredValueStore

KEY = jax.random.PRNGKey(0)
KINDS = ("int8", "fp8")


# ---------------------------------------------------------------------------
# codec round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", KINDS)
def test_round_trip_error_bound(rng, kind):
    """Nearest rounding stays within half a grid step of the fp32 row:
    scale/2 for int8's uniform grid, |v| * 2**-4 for fp8 (e4m3)."""
    v = rng.normal(size=(256, 16)).astype(np.float32) * 0.02
    q, scale = quant.quantize_rows_np(v, kind)
    assert q.dtype == quant.storage_dtype(kind) and q.dtype.itemsize == 1
    back = quant.dequantize_rows_np(q, scale)
    if kind == "int8":
        bound = scale[:, None] / 2 + 1e-7
    else:
        bound = np.abs(v) * 2.0**-4 + scale[:, None] + 1e-7
    assert np.all(np.abs(back - v) <= bound)


def test_bytes_per_entry():
    assert quant.bytes_per_entry(64, None) == 256
    assert quant.bytes_per_entry(64, "int8") == 68
    assert quant.bytes_per_entry(64, "fp8") == 68
    assert 256 / 68 >= 3.5  # the acceptance floor


# ---------------------------------------------------------------------------
# all four lookup implementations vs the fp32 reference
# ---------------------------------------------------------------------------

def _quant_cfg(kind, **kw):
    base = dict(log2_locations=16, m=8, heads=4, query_norm="rms")
    base.update(kw)
    return lram.LRAMConfig(table_quant=kind, **base)


@pytest.mark.parametrize("kind", KINDS)
def test_interp_error_vs_fp32_within_documented_bound(rng, kind):
    """The documented tolerance: a quantized gather+interpolate differs from
    the fp32 one by at most repro.quant.max_abs_error_bound."""
    values = rng.normal(size=(2**16, 8)).astype(np.float32) * 0.02
    qt = quant.QuantizedTable.from_dense(values, kind)
    idx = jnp.asarray(rng.integers(0, 2**16, size=(64, 32)))
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    out_fp = lram.gather_interp(jnp.asarray(values), idx, w)
    out_q = quant.gather_interp_quant(qt, idx, w)
    bound = quant.max_abs_error_bound(np.asarray(qt.scale),
                                      np.asarray(w), kind)
    assert np.abs(np.asarray(out_q) - np.asarray(out_fp)).max() \
        <= bound + 1e-6


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("impl", ["reference", "pallas", "tiered"])
def test_quantized_layer_impls_agree(kind, impl):
    """Every in-process impl of the quantized layer produces the same
    output as the quantized-twin reference (same init rounding), eager +
    jit + grad-of-input; and tracks the fp32 layer closely."""
    kw = {}
    if impl == "tiered":
        kw = dict(
            interp_impl="tiered",
            tiered=TieredSpec(shard_rows=4096, cache_slots=4),  # <50% resident
        )
    cfg_fp = lram.LRAMConfig(log2_locations=16, m=8, heads=4,
                             query_norm="rms")
    cfg_q = _quant_cfg(kind, **kw)
    cfg_qref = _quant_cfg(kind)
    p_fp, s_fp = lram.lram_init(KEY, cfg_fp)
    p_q, s_q = lram.lram_init(KEY, cfg_q)
    p_r, s_r = lram.lram_init(KEY, cfg_qref)
    x = jax.random.normal(KEY, (3, 5, cfg_fp.in_dim))

    y_fp, _ = lram.lram_apply(p_fp, s_fp, x, cfg_fp)
    y_ref, _ = lram.lram_apply(p_r, s_r, x, cfg_qref)  # quantized reference
    impl_arg = None if impl == "tiered" else impl
    y_q, _ = lram.lram_apply(p_q, s_q, x, cfg_q, interp_impl=impl_arg)
    np.testing.assert_allclose(np.asarray(y_q), np.asarray(y_ref), atol=1e-5)
    # sanity vs the fp32 twin: rounding noise only (the hard bound is
    # asserted at interp level in test_interp_error_vs_fp32_*)
    np.testing.assert_allclose(np.asarray(y_q), np.asarray(y_fp),
                               atol=2e-2, rtol=2e-2)

    y_j = jax.jit(
        lambda xx: lram.lram_apply(p_q, s_q, xx, cfg_q,
                                   interp_impl=impl_arg)[0]
    )(x)
    np.testing.assert_allclose(np.asarray(y_j), np.asarray(y_ref), atol=1e-5)

    g_ref = jax.grad(
        lambda xx: jnp.sum(lram.lram_apply(p_r, s_r, xx, cfg_qref)[0] ** 2)
    )(x)
    g_q = jax.grad(
        lambda xx: jnp.sum(
            lram.lram_apply(p_q, s_q, xx, cfg_q, interp_impl=impl_arg)[0] ** 2
        )
    )(x)
    np.testing.assert_allclose(np.asarray(g_q), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)
    assert bool(jnp.isfinite(g_q).all())


@pytest.mark.slow
def test_quantized_sharded_lookup_matches_reference():
    """impl #4: the model-parallel shard_map lookup dequantizes shard-local
    rows and psums fp32 partials — same bound, jit + grad, 8 fake devices."""
    run_in_subprocess(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import quant
        from repro.core import indexing, lram
        from repro.distributed.sharded_lram import sharded_gather_interp

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        spec = indexing.choose_torus(16)
        rng = np.random.default_rng(0)
        values = rng.normal(size=(spec.num_locations, 16)) \\
            .astype(np.float32) * 0.02
        q = jnp.asarray(rng.uniform(0, 8, size=(8, 3, 8)).astype(np.float32))
        idx, w = lram.indices_and_weights(q, spec, 32)
        qt = quant.QuantizedTable.from_dense(values, "int8")
        interp = sharded_gather_interp(mesh, axis="model")

        got = interp(qt, idx, w)
        want_q = quant.gather_interp_quant(qt, idx, w)  # quantized reference
        np.testing.assert_allclose(np.asarray(got), np.asarray(want_q),
                                   rtol=1e-5, atol=1e-5)
        want_fp = jnp.einsum("...k,...km->...m", w,
                             jnp.asarray(values)[idx])
        bound = quant.max_abs_error_bound(
            np.asarray(qt.scale), np.asarray(w), "int8") + 1e-6
        assert np.abs(np.asarray(got) - np.asarray(want_fp)).max() <= bound

        jitted = jax.jit(lambda i, ww: interp(qt, i, ww))
        np.testing.assert_allclose(np.asarray(jitted(idx, w)),
                                   np.asarray(want_q), rtol=1e-5, atol=1e-5)
        g = jax.grad(lambda ww: jnp.sum(interp(qt, idx, ww) ** 2))(w)
        g_ref = jax.grad(
            lambda ww: jnp.sum(quant.gather_interp_quant(qt, idx, ww) ** 2)
        )(w)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-4, atol=1e-4)
        print("sharded quantized lram OK")
    """), devices=8)


@pytest.mark.parametrize("kind", KINDS)
def test_tiered_quant_gather_matches_quantized_twin(rng, kind):
    """All tiered serving paths (eager cache gather, overflow, pallas
    indirected kernel, traced io_callback) reproduce the dense quantized
    table bit-for-bit (same rounding at init)."""
    dense = rng.normal(size=(4096, 16)).astype(np.float32) * 0.02
    deq = np.asarray(quant.QuantizedTable.from_dense(dense, kind).dequantize())
    idx = rng.integers(0, 4096, size=(8, 32)).astype(np.int32)
    w = rng.normal(size=idx.shape).astype(np.float32)
    want = np.einsum("...k,...km->...m", w, deq[idx])
    for use_pallas in (False, True):
        store = TieredValueStore.from_dense(
            dense, TieredSpec(shard_rows=256, cache_slots=4, quant=kind,
                              use_pallas=use_pallas)
        )
        out = np.asarray(store.gather(idx, w))  # overflow: 4 slots, 16 shards
        np.testing.assert_allclose(out, want, atol=1e-5)
        assert store.stats["uncached"] > 0
        from repro import memstore
        out_j = jax.jit(
            lambda i, ww: memstore.tiered_interp(store, i, ww)
        )(jnp.asarray(idx), jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(out_j), want, atol=1e-5)


def test_quantized_fill_bytes_shrink(rng):
    """The host->device fill traffic for an int8 store is ~4x smaller than
    its fp32 twin — the PCIe half of the quantization win."""
    dense = rng.normal(size=(4096, 64)).astype(np.float32)
    counts = {}
    for quant_kind in ("none", "int8"):
        store = TieredValueStore.from_dense(
            dense, TieredSpec(shard_rows=256, cache_slots=4, quant=quant_kind)
        )
        idx = rng.integers(0, 1024, size=(8, 32)).astype(np.int32)
        store.gather(idx, rng.normal(size=idx.shape).astype(np.float32))
        counts[quant_kind] = store.stats["fill_bytes"]
    assert counts["none"] >= 3.5 * counts["int8"]


# ---------------------------------------------------------------------------
# stochastic rounding + write-back
# ---------------------------------------------------------------------------

def test_stochastic_rounding_unbiased():
    """E[quantize_sr(v)] == v: averaged over seeds, stochastic rounding has
    no systematic drift (nearest rounding would bias every draw the same
    way)."""
    v = np.linspace(-0.9, 0.9, 16, dtype=np.float32)[None, :] * 0.013
    draws = []
    for seed in range(400):
        q, s = quant.quantize_rows_np(v, "int8",
                                      rng=np.random.default_rng(seed))
        draws.append(quant.dequantize_rows_np(q, s))
    mean = np.mean(draws, axis=0)
    step = np.abs(v).max() / 127.0  # one quantization step
    # CLT: sd of the mean <= step / sqrt(12 * 400) ~= step / 69
    assert np.abs(mean - v).max() < 0.15 * step
    # while a single nearest-rounded draw is off by up to step/2
    q, s = quant.quantize_rows_np(v, "int8")
    assert np.abs(quant.dequantize_rows_np(q, s) - v).max() <= step / 2 + 1e-9


def test_quantized_writeback_applies_expected_update(rng):
    """dequant(after) ~= dequant(before) - lr * wg on touched rows, within
    one (stochastic) quantization step; untouched rows bit-identical."""
    dense = rng.normal(size=(2048, 8)).astype(np.float32) * 0.02
    store = TieredValueStore.from_dense(
        dense, TieredSpec(shard_rows=256, cache_slots=4, quant="int8")
    )
    store.writeback_lr = 0.5
    before = store.to_dense()
    idx = rng.integers(0, 2048, size=(64,)).astype(np.int32)
    wg = rng.normal(size=(64, 8)).astype(np.float32) * 0.01
    store.gather_rows_host(idx)  # makes some shards resident
    store.apply_writeback(idx, wg)
    assert store._dirty, "resident rows must mark their slots dirty"
    after = store.to_dense()

    expected = before.copy()
    np.add.at(expected, idx, -0.5 * wg)  # duplicates accumulate
    touched = np.zeros(2048, bool)
    touched[idx] = True
    np.testing.assert_array_equal(after[~touched], before[~touched])
    # requantization error: one step of the fresh per-row scale
    scale = np.abs(expected[touched]).max(axis=-1) / 127.0
    assert np.all(
        np.abs(after[touched] - expected[touched]) <= scale[:, None] + 1e-7
    )


def test_quantized_writeback_unbiased_in_expectation(rng):
    """The same sub-quantum update applied across many rng seeds moves the
    mean stored value by ~the true update (nearest rounding would leave a
    small update invisible forever)."""
    row = (rng.normal(size=(1, 8)) * 0.02).astype(np.float32)
    upd = np.full((1, 8), 1e-5, np.float32)  # << one quantization step
    step = np.abs(row).max() / 127.0
    assert upd[0, 0] < step / 4
    before = quant.dequantize_rows_np(*quant.quantize_rows_np(row, "int8"))[0]
    deltas = []
    for seed in range(300):
        store = TieredValueStore.from_dense(
            np.repeat(row, 256, axis=0),
            TieredSpec(shard_rows=256, cache_slots=1, quant="int8"),
        )
        store.writeback_lr = 1.0
        store._wb_rng = np.random.default_rng(seed)
        store.gather_rows_host(np.zeros((1,), np.int32))
        store.apply_writeback(np.zeros((1,), np.int32), -upd)  # SGD: -= -upd
        deltas.append(store.to_dense()[0] - before)
    mean_delta = np.mean(deltas, axis=0)
    np.testing.assert_allclose(mean_delta, upd[0], atol=step / 8)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_quantized_dirty_checkpoint_round_trip(rng, tmp_path):
    """Quantized store with dirty shards: save streams payload + scales;
    restore into a fresh quantized store is bit-exact; restore into a dense
    proto and a dense checkpoint into a quantized store both convert."""
    dense = rng.normal(size=(2048, 8)).astype(np.float32) * 0.02
    spec = TieredSpec(shard_rows=256, cache_slots=3, quant="int8")
    store = TieredValueStore.from_dense(dense, spec)
    store.writeback_lr = 0.5
    idx = rng.integers(0, 2048, size=(64,)).astype(np.int32)
    store.gather_rows_host(idx)
    store.apply_writeback(idx, rng.normal(size=(64, 8)).astype(np.float32))
    assert store._dirty, "test needs dirty cached shards"

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"values": store})
    expected = store.to_dense()

    import json
    import os
    man = json.load(open(os.path.join(
        str(tmp_path), "step_000000000003", "manifest.json")))
    meta = man["leaves"]["values"]
    assert meta["quant"] == "int8"
    assert len(meta["scale_crc32"]) == store.num_shards
    assert os.path.exists(os.path.join(
        str(tmp_path), "step_000000000003", meta["dir"], "scale_000000.npy"))

    fresh = TieredValueStore(2048, 8, spec)
    step, _ = mgr.restore({"values": fresh})
    assert step == 3
    np.testing.assert_array_equal(fresh.to_dense(), expected)
    np.testing.assert_array_equal(np.asarray(fresh._host),
                                  np.asarray(store._host))

    # quantized checkpoint -> dense proto (dequantized host-side)
    _, r = mgr.restore({"values": jnp.zeros((2048, 8))})
    np.testing.assert_allclose(np.asarray(r["values"]), expected, atol=1e-7)

    # quantized checkpoint -> unquantized tiered store (dequant per shard)
    dense_store = TieredValueStore(
        2048, 8, TieredSpec(shard_rows=256, cache_slots=3)
    )
    mgr.restore({"values": dense_store})
    np.testing.assert_allclose(dense_store.to_dense(), expected, atol=1e-7)

    # dense checkpoint -> quantized store (requantized per shard, nearest)
    mgr2 = CheckpointManager(str(tmp_path / "dense"))
    dense_store.flush()
    mgr2.save(1, {"values": dense_store})
    q_store = TieredValueStore(2048, 8, spec)
    mgr2.restore({"values": q_store})
    q_ref, s_ref = quant.quantize_rows_np(expected, "int8")
    np.testing.assert_array_equal(
        np.asarray(q_store._host).reshape(2048, 8), q_ref
    )


def test_corrupt_scale_falls_back(rng, tmp_path):
    """A corrupt scale file is caught by its own checksum and triggers the
    same newest-first fallback as a corrupt payload shard."""
    dense = rng.normal(size=(1024, 8)).astype(np.float32)
    spec = TieredSpec(shard_rows=128, cache_slots=2, quant="int8")
    store = TieredValueStore.from_dense(dense, spec)
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, {"values": store})
    expected = store.to_dense()
    store.writeback_lr = 0.5
    idx = rng.integers(0, 1024, size=(32,)).astype(np.int32)
    store.gather_rows_host(idx)
    store.apply_writeback(idx, rng.normal(size=(32, 8)).astype(np.float32))
    mgr.save(2, {"values": store})

    import os
    bad = os.path.join(str(tmp_path), "step_000000000002",
                       "values.npy.shards", "scale_000002.npy")
    with open(bad, "wb") as f:
        f.write(b"garbage")
    fresh = TieredValueStore(1024, 8, spec)
    step, _ = mgr.restore({"values": fresh})
    assert step == 1
    np.testing.assert_array_equal(fresh.to_dense(), expected)


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------

def test_lram_tiered_q8_config_registered():
    from repro import configs
    cfg = configs.get_smoke_config("lram-tiered-q8")
    assert cfg.lram.table_quant == "int8"
    assert cfg.lram.tiered.quant == "int8"
    assert cfg.lram.table_bytes_per_entry == 68
    # quantized cache budget: same slots hold ~4x less memory
    params, _ = lram.lram_init(KEY, cfg.lram)
    store = params["values"]
    assert store.quant == "int8"
    assert store.cache_np.dtype.itemsize == 1


def test_table_quant_validation():
    with pytest.raises(ValueError):
        lram.LRAMConfig(table_quant="int4")
    with pytest.raises(ValueError):
        TieredSpec(quant="bogus")
