"""Continuous-batching serve engine: admit/retire invariants, equivalence.

Covers the repro.serving subsystem:

  * trace / queue mechanics (arrival ordering, clock-gated readiness),
  * admit/retire invariants under mixed prompt/generation lengths
    (every request gets exactly its budget, slots are reused, budgets
    that overflow the cache are truncated at the cache end),
  * continuous-vs-static-vs-single-slot equivalence: identical request
    sets must generate identical tokens and first-step logits whatever
    the scheduling mode (scheduling may only change *when* work runs),
  * equivalence against an unbatched scalar-position reference decode,
  * slotted-cache plumbing (`cache_batch_axes`, `write_cache_slot`),
  * tiered-memstore integration: per-request decode cache hit-rates.
"""

import numpy as np
import pytest

import jax

from repro import configs
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving import (
    EngineConfig,
    Request,
    RequestQueue,
    ServeEngine,
    synthetic_trace,
)

TINY = ModelConfig(
    name="tiny-serve",
    family="dense",
    num_layers=2,
    d_model=32,
    num_heads=4,
    num_kv_heads=2,
    d_ff=64,
    vocab_size=97,
    objective="clm",
    remat=False,
)
MAX_LEN = 12


@pytest.fixture(scope="module")
def tiny_model():
    return transformer.init(jax.random.PRNGKey(0), TINY)


def _trace(n=6, seed=1, max_prompt=6, max_gen=5):
    return synthetic_trace(
        np.random.default_rng(seed), n,
        vocab_size=TINY.vocab_size, max_prompt=max_prompt, max_gen=max_gen,
        mixed=True,
    )


def _run(tiny_model, trace, *, slots=3, mode="continuous", max_len=MAX_LEN):
    params, state = tiny_model
    engine = ServeEngine(
        params, state, TINY,
        EngineConfig(slots=slots, max_len=max_len, mode=mode),
    )
    return engine.run(trace)


# ---------------------------------------------------------------- trace/queue

def test_synthetic_trace_shapes_and_arrivals():
    rng = np.random.default_rng(0)
    trace = synthetic_trace(rng, 32, vocab_size=50, max_prompt=7, max_gen=9,
                            rate=100.0, mixed=True)
    assert len(trace) == 32
    arrivals = [r.arrival_s for r in trace]
    assert arrivals == sorted(arrivals) and arrivals[-1] > 0
    assert all(1 <= r.prompt_len <= 7 for r in trace)
    assert all(1 <= r.max_new_tokens <= 9 for r in trace)
    assert all(r.prompt.min() >= 0 and r.prompt.max() < 50 for r in trace)
    fixed = synthetic_trace(rng, 4, vocab_size=50, max_prompt=7, max_gen=9,
                            mixed=False)
    assert all(r.prompt_len == 7 and r.max_new_tokens == 9 for r in fixed)
    assert all(r.arrival_s == 0.0 for r in fixed)


def test_request_queue_is_clock_gated_and_ordered():
    reqs = [Request(id=i, prompt=np.array([1]), max_new_tokens=1,
                    arrival_s=t) for i, t in enumerate([0.5, 0.0, 2.0])]
    q = RequestQueue(reqs)
    assert len(q) == 3
    assert q.next_arrival() == 0.0
    assert q.pop_ready(now=0.0).id == 1
    assert q.pop_ready(now=0.0) is None          # id=0 arrives at 0.5
    assert q.num_ready(now=1.0) == 1
    assert q.pop_ready(now=1.0).id == 0
    q.push(Request(id=9, prompt=np.array([1]), max_new_tokens=1,
                   arrival_s=1.5))
    assert q.pop_ready(now=3.0).id == 9          # 1.5 < 2.0: order kept
    assert q.pop_ready(now=3.0).id == 2
    assert q.next_arrival() is None


# -------------------------------------------------------------- admit/retire

def test_admit_retire_budgets_under_mixed_lengths(tiny_model):
    trace = _trace(8, seed=2)
    report = _run(tiny_model, trace, slots=3)
    assert sorted(r.id for r in report.requests) == list(range(8))
    by_id = {r.id: r for r in report.requests}
    for req in trace:
        fin = by_id[req.id]
        # capacity: max_len - s decode writes + the prefill-emitted token
        expect = min(req.max_new_tokens, MAX_LEN - req.prompt_len + 1)
        assert len(fin.tokens) == expect, (req.id, fin.tokens)
        # first token comes from prefill; each decode tick adds one
        assert fin.decode_steps == expect - 1
        assert all(0 <= t < TINY.vocab_size for t in fin.tokens)
    assert report.generated_tokens == sum(
        len(r.tokens) for r in report.requests
    )
    # 8 requests through 3 slots: slots were reused
    assert len(report.prefill_s) == 8


def test_budget_truncates_at_cache_end(tiny_model):
    req = Request(id=0, prompt=np.arange(1, 9, dtype=np.int32),
                  max_new_tokens=50)
    report = _run(tiny_model, [req], slots=1)
    assert len(report.requests[0].tokens) == MAX_LEN - 8 + 1


def test_prompt_longer_than_cache_rejected(tiny_model):
    req = Request(id=0, prompt=np.ones(MAX_LEN, np.int32), max_new_tokens=1)
    with pytest.raises(ValueError, match="no room"):
        _run(tiny_model, [req], slots=1)


# -------------------------------------------------------------- equivalence

def test_scheduling_modes_are_logit_equivalent(tiny_model):
    """Continuous, static, and single-slot scheduling must produce the same
    tokens and the same first-step logits for an identical request set."""
    trace = _trace(6, seed=3)
    ref = _run(tiny_model, trace, slots=3, mode="continuous")
    for variant in (
        _run(tiny_model, trace, slots=3, mode="static"),
        _run(tiny_model, trace, slots=1, mode="continuous"),
    ):
        for a, b in zip(ref.requests, variant.requests):
            assert a.id == b.id and a.tokens == b.tokens
            np.testing.assert_allclose(
                a.first_logits, b.first_logits, rtol=1e-5, atol=1e-5
            )


def test_engine_matches_unbatched_reference_decode(tiny_model):
    """The slotted engine must reproduce a plain per-request prefill +
    scalar-position decode loop (no padding, no slot pool)."""
    params, state = tiny_model
    trace = _trace(4, seed=4)
    report = _run(tiny_model, trace, slots=2)
    by_id = {r.id: r for r in report.requests}
    for req in trace:
        s = req.prompt_len
        logits, cache = transformer.prefill(
            params, state, {"tokens": req.prompt[None]}, TINY, MAX_LEN
        )
        tok = int(np.argmax(np.asarray(logits[0, s - 1])))
        tokens = [tok]
        np.testing.assert_allclose(
            np.asarray(logits[0, s - 1]), by_id[req.id].first_logits,
            rtol=1e-4, atol=1e-4,
        )
        budget = min(req.max_new_tokens, MAX_LEN - s + 1)
        for i in range(budget - 1):
            lg, cache = transformer.decode_step(
                params, state, np.asarray([[tok]], np.int32), s + i,
                cache, TINY,
            )
            tok = int(np.argmax(np.asarray(lg[0, -1])))
            tokens.append(tok)
        assert tokens == by_id[req.id].tokens, req.id


# ------------------------------------------------------------ cache plumbing

def test_cache_batch_axes_and_write_slot():
    cfg = configs.get_smoke_config("lram-tiered")
    axes = transformer.cache_batch_axes(cfg, 8)
    # scanned runs stack layers ahead of batch; memory layers do not
    assert axes["seg0"]["k"] == 1
    assert axes["seg1"]["k"] == 0
    cache = transformer.init_cache(cfg, 3, 8)
    sub = jax.tree.map(
        lambda a, ax: jnp_ones_like_slice(a, ax), cache, axes
    )
    spliced = transformer.write_cache_slot(cache, sub, 1, axes)
    k = np.asarray(spliced["seg0"]["k"])
    assert (k[:, 1] == 1).all() and (k[:, 0] == 0).all() and (k[:, 2] == 0).all()
    mk = np.asarray(spliced["seg1"]["k"])
    assert (mk[1] == 1).all() and (mk[0] == 0).all() and (mk[2] == 0).all()


def jnp_ones_like_slice(a, ax):
    shape = list(a.shape)
    shape[ax] = 1
    return np.ones(shape, a.dtype)


@pytest.mark.slow
def test_swa_engine_matches_unbatched_reference():
    """Sliding-window archs keep the *last* window positions in a ring
    buffer, so padded prefill is not maskable there either — the engine
    must prefill SWA prompts at exact length and still match the
    unbatched reference (prompts deliberately longer than the window)."""
    cfg = configs.get_smoke_config("h2o-danube-3-4b")
    assert cfg.attention == "swa"
    params, state = transformer.init(jax.random.PRNGKey(0), cfg)
    max_len = 2 * cfg.window
    rng = np.random.default_rng(6)
    trace = [
        Request(
            id=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=(int(sp),)).astype(np.int32),
            max_new_tokens=5,
        )
        for i, sp in enumerate(rng.integers(cfg.window + 1, max_len - 5,
                                            size=3))
    ]
    engine = ServeEngine(
        params, state, cfg, EngineConfig(slots=2, max_len=max_len),
    )
    report = engine.run(trace)
    by_id = {r.id: r for r in report.requests}
    for req in trace:
        s = req.prompt_len
        logits, cache = transformer.prefill(
            params, state, {"tokens": req.prompt[None]}, cfg, max_len
        )
        tok = int(np.argmax(np.asarray(logits[0, s - 1])))
        tokens = [tok]
        for i in range(min(req.max_new_tokens, max_len - s + 1) - 1):
            lg, cache = transformer.decode_step(
                params, state, np.asarray([[tok]], np.int32), s + i,
                cache, cfg,
            )
            tok = int(np.argmax(np.asarray(lg[0, -1])))
            tokens.append(tok)
        assert tokens == by_id[req.id].tokens, req.id


@pytest.mark.slow
def test_ssm_engine_matches_unbatched_reference():
    """Recurrent families prefill at exact prompt length (state integrates
    every position, so padding is not maskable); the engine must still
    match the unbatched reference decode."""
    cfg = configs.get_smoke_config("mamba2-1.3b")
    params, state = transformer.init(jax.random.PRNGKey(0), cfg)
    max_len = 10
    trace = synthetic_trace(
        np.random.default_rng(5), 3,
        vocab_size=cfg.vocab_size, max_prompt=5, max_gen=4, mixed=True,
    )
    engine = ServeEngine(
        params, state, cfg, EngineConfig(slots=2, max_len=max_len),
    )
    report = engine.run(trace)
    by_id = {r.id: r for r in report.requests}
    for req in trace:
        s = req.prompt_len
        logits, cache = transformer.prefill(
            params, state, {"tokens": req.prompt[None]}, cfg, max_len
        )
        tok = int(np.argmax(np.asarray(logits[0, s - 1])))
        tokens = [tok]
        for i in range(min(req.max_new_tokens, max_len - s + 1) - 1):
            lg, cache = transformer.decode_step(
                params, state, np.asarray([[tok]], np.int32), s + i,
                cache, cfg,
            )
            tok = int(np.argmax(np.asarray(lg[0, -1])))
            tokens.append(tok)
        assert tokens == by_id[req.id].tokens, req.id


# ---------------------------------------------------------- tiered memstore

@pytest.mark.slow
def test_tiered_per_request_hit_rates():
    cfg = configs.get_smoke_config("lram-tiered")
    params, state = transformer.init(jax.random.PRNGKey(0), cfg)
    trace = synthetic_trace(
        np.random.default_rng(0), 4,
        vocab_size=cfg.vocab_size, max_prompt=4, max_gen=4, mixed=True,
    )
    engine = ServeEngine(
        params, state, cfg, EngineConfig(slots=2, max_len=8),
    )
    report = engine.run(trace)
    assert report.cache is not None
    total = (report.cache["hits"] + report.cache["misses"]
             + report.cache["uncached"])
    assert total > 0 and 0.0 <= report.cache["hit_rate"] <= 1.0
    for fin in report.requests:
        assert fin.cache_hit_rate is not None
        assert 0.0 <= fin.cache_hit_rate <= 1.0
    # the summary document carries the per-request rates
    doc = report.summary(cfg.name)
    assert all("cache_hit_rate" in r for r in doc["requests"])
