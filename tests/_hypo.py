"""Optional-hypothesis shim.

The property tests use hypothesis when it is installed (the `test` extra);
without it the suite must still *collect* everywhere — CI images and the
bare runtime container only ship pytest.  Importing `given`/`settings`/`st`
from here gives the real decorators when available and otherwise replaces
each @given test with a clean skip (no signature leaks into pytest's
fixture resolution).
"""

from __future__ import annotations

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised on bare images
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(f):
            def _skipped():
                pytest.skip("hypothesis not installed (pip install .[test])")

            _skipped.__name__ = f.__name__
            _skipped.__doc__ = f.__doc__
            _skipped.__module__ = f.__module__
            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _StrategyStub:
        """Placeholder: strategy expressions evaluate at import time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
