"""Torus activation (paper §2.3): homogeneity, continuity, ranges."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import indexing, torus

SPEC = indexing.choose_torus(18)


def test_output_ranges(rng):
    x = rng.normal(size=(256, 16)).astype(np.float32)
    q, s = torus.torus_map(jnp.asarray(x), SPEC.K)
    q, s = np.asarray(q), np.asarray(s)
    assert q.shape == (256, 8) and s.shape == (256, 1)
    assert q.min() >= 0 and np.all(q < np.array(SPEC.K))
    assert np.all(s > 0)


@pytest.mark.slow
@settings(deadline=None, max_examples=40)
@given(
    st.lists(st.floats(-5, 5, width=32), min_size=16, max_size=16),
    st.floats(0.01, 100.0),
)
def test_positive_homogeneity(coords, lam):
    """theta(lambda z) = lambda theta(z): same torus point, scaled output.

    (Exact above the numerical-safety floor at |z| ~ 1e-10; below it the
    output is clamped to ~0, which is the Lipschitz-continuity behaviour.)"""
    arr = np.array(coords, dtype=np.float32)
    mags = np.sqrt(arr[:8] ** 2 + arr[8:] ** 2)
    from hypothesis import assume

    assume(float(mags.min()) > 1e-3)
    x = jnp.asarray(arr)
    q1, s1 = torus.torus_map(x, SPEC.K)
    q2, s2 = torus.torus_map(lam * x, SPEC.K)
    # circular distance: scaling can flip the atan2 branch cut by one ulp
    diff = np.abs(np.asarray(q1) - np.asarray(q2))
    circ = np.minimum(diff, np.array(SPEC.K, dtype=np.float32) - diff)
    assert circ.max() < 1e-2
    np.testing.assert_allclose(
        lam * np.asarray(s1), np.asarray(s2), rtol=1e-4
    )


def test_scale_formula_matches_paper(rng):
    """scale = (sum_i 1/|z_i|)^{-1} exactly."""
    x = rng.normal(size=(64, 16)).astype(np.float64)
    re, im = x[:, :8], x[:, 8:]
    mags = np.sqrt(re**2 + im**2)
    expected = 1.0 / (1.0 / mags).sum(1)
    _, s = torus.torus_map(jnp.asarray(x.astype(np.float32)), SPEC.K)
    np.testing.assert_allclose(np.asarray(s)[:, 0], expected, rtol=1e-5)


def test_continuous_at_origin():
    """Output scale -> 0 as any z_i -> 0 (Lipschitz continuity)."""
    x = np.ones((4, 16), dtype=np.float32)
    x[1, 0] = x[1, 8] = 1e-8  # z_1 ~ 0
    x[2] = 0.0
    x[3] *= 1e-9
    _, s = torus.torus_map(jnp.asarray(x), SPEC.K)
    s = np.asarray(s)[:, 0]
    assert s[1] < 1e-7 and s[2] < 1e-7 and s[3] < 1e-7


def test_gradients_finite_everywhere(rng):
    x = rng.normal(size=(32, 16)).astype(np.float32)
    x[0] = 0.0  # degenerate point
    x[1, 3] = 0.0

    def f(x):
        q, s = torus.torus_map(x, SPEC.K)
        return jnp.sum(jnp.sin(q) * s)

    g = jax.grad(f)(jnp.asarray(x))
    assert bool(jnp.isfinite(g).all())


def test_angle_maps_to_expected_coordinate():
    # z_1 = exp(i*pi/2) -> q_1 = K_1/4
    x = np.zeros((1, 16), dtype=np.float32)
    x[0, 8:] = 1.0  # purely imaginary: arg = pi/2 for all entries
    q, _ = torus.torus_map(jnp.asarray(x), SPEC.K)
    np.testing.assert_allclose(
        np.asarray(q)[0], np.array(SPEC.K) / 4.0, rtol=1e-6
    )
