"""Summary-document schema shared by the benchmark and serve emitters,
and the benchmark-regression gate (tools/check_bench.py).

Covers the satellite contract: `benchmarks.run --json` and
`repro.launch.serve --json` emit the same summary-document schema
(top-level `rows` of [name, us_per_call, derived] triples), and
`check_bench` demonstrably fails when a tracked hot path is 2x slower
than the committed baseline (threshold 1.3x).
"""

import importlib.util
import json
import os
import sys

import pytest

from tests.conftest import REPO

sys.path.insert(0, REPO)  # `import benchmarks` (namespace pkg at repo root)

from benchmarks.run import validate_summary  # noqa: E402


def _load_check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", os.path.join(REPO, "tools", "check_bench.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------------------- schema

def test_validate_summary_accepts_the_shared_schema():
    validate_summary({"rows": [["x", 1.5, "d"], ["y", 0, ""]],
                      "extra": "ignored"})


@pytest.mark.parametrize("doc", [
    [],                                       # not an object
    {},                                       # no rows
    {"rows": []},                             # empty rows
    {"rows": [["x", 1.5]]},                   # missing derived
    {"rows": [["", 1.5, "d"]]},               # empty name
    {"rows": [["x", -1.0, "d"]]},             # negative latency
    {"rows": [["x", True, "d"]]},             # bool is not a latency
    {"rows": [["x", float("nan"), "d"]]},     # non-finite
    {"rows": [["x", float("inf"), "d"]]},     # json.dump would emit Infinity
    {"rows": [["x", 1.5, 3]]},                # derived not a string
])
def test_validate_summary_rejects_malformed(doc):
    with pytest.raises(ValueError):
        validate_summary(doc)


def test_benchmarks_run_json_emitter(capsys):
    from benchmarks import run as bench_run

    rc = bench_run.main(["table1", "--smoke", "--json"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    validate_summary(doc)
    assert doc["smoke"] is True and doc["tables"] == ["table1"]
    assert any(name.startswith("table1.") for name, _, _ in doc["rows"])


@pytest.mark.slow
def test_serve_json_emitter_shares_the_schema(capsys):
    from repro.launch import serve

    serve.main(["--arch", "lram-tiered", "--smoke", "--mode", "continuous",
                "--batch", "2", "--prompt-len", "4", "--gen", "3", "--json"])
    doc = json.loads(capsys.readouterr().out)
    validate_summary(doc)           # same contract as benchmarks.run --json
    assert doc["mode"] == "continuous"
    assert {"p50_ms", "p99_ms", "tokens_per_sec", "per_step_ms",
            "cache", "requests"} <= set(doc)
    assert doc["cache"] is not None and "hit_rate" in doc["cache"]


def test_baseline_tracks_multitenant_serving_row():
    """The multi-tenant overlay benchmark row is registered in the
    committed baseline (presence-only: us=0), so CI fails if the bench
    stops emitting it."""
    with open(os.path.join(REPO, "benchmarks", "baseline.json")) as f:
        baseline = json.load(f)
    row = next(r for r in baseline["rows"]
               if r[0] == "serving_multitenant_load0")
    assert row[1] == 0.0  # presence-only, never latency-gated


@pytest.mark.slow
def test_table8_emits_multitenant_overlay_row():
    from benchmarks import table8_serving

    rows = table8_serving.run(smoke=True)
    row = next(r for r in rows if r[0] == "serving_multitenant_load0")
    assert "overlay_hit_rate=" in row[2]
    assert "bytes_per_tenant=" in row[2]
    assert "tenants=" in row[2] and "writebacks=" in row[2]


# -------------------------------------------------------------- check_bench

BASE = {"rows": [["hot.gather", 100.0, ""], ["hot.decode", 50.0, ""],
                 ["analytic.row", 0.0, "presence-only"]]}


def test_check_bench_fails_on_synthetic_2x_regression(tmp_path):
    cb = _load_check_bench()
    cur = {"rows": [["hot.gather", 200.0, ""], ["hot.decode", 51.0, ""],
                    ["analytic.row", 0.0, ""]]}
    lines, failures = cb.compare(BASE, cur, threshold=1.3)
    assert len(failures) == 1 and "hot.gather" in failures[0]
    assert any("REGRESSED" in ln for ln in lines)
    # end-to-end: exit code 1
    base_p, cur_p = tmp_path / "base.json", tmp_path / "cur.json"
    base_p.write_text(json.dumps(BASE))
    cur_p.write_text(json.dumps(cur))
    assert cb.main([str(cur_p), "--baseline", str(base_p)]) == 1


def test_check_bench_passes_within_threshold(tmp_path):
    cb = _load_check_bench()
    cur = {"rows": [["hot.gather", 125.0, ""], ["hot.decode", 40.0, ""],
                    ["analytic.row", 0.0, ""],
                    ["brand.new", 9.0, "untracked rows never gate"]]}
    lines, failures = cb.compare(BASE, cur, threshold=1.3)
    assert failures == []
    assert any("NEW (untracked)" in ln for ln in lines)
    base_p, cur_p = tmp_path / "base.json", tmp_path / "cur.json"
    base_p.write_text(json.dumps(BASE))
    cur_p.write_text(json.dumps(cur))
    assert cb.main([str(cur_p), "--baseline", str(base_p)]) == 0


def test_check_bench_missing_tracked_row_fails():
    cb = _load_check_bench()
    cur = {"rows": [["hot.gather", 100.0, ""], ["analytic.row", 0.0, ""]]}
    _, failures = cb.compare(BASE, cur, threshold=1.3)
    assert failures and "hot.decode" in failures[0]


def test_check_bench_calibration_absorbs_machine_speed_skew():
    """A uniformly slower runner passes when calibrated on a reference
    row; a row that regresses beyond the machine skew still fails."""
    cb = _load_check_bench()
    base = {"rows": [["ref.gather", 100.0, ""], ["hot.decode", 50.0, ""]]}
    slower = {"rows": [["ref.gather", 200.0, ""], ["hot.decode", 100.0, ""]]}
    _, failures = cb.compare(base, slower, threshold=1.3)
    assert failures                         # absolute gate: 2x > 1.3x
    _, failures = cb.compare(base, slower, threshold=1.3,
                             calibrate="ref.gather")
    assert failures == []                   # calibrated: uniform 2x absorbed
    real_regression = {"rows": [["ref.gather", 200.0, ""],
                                ["hot.decode", 300.0, ""]]}
    _, failures = cb.compare(base, real_regression, threshold=1.3,
                             calibrate="ref.gather")
    assert failures and "hot.decode" in failures[0]   # 6x > 1.3x * 2
    # a faster machine never tightens the gate below the absolute threshold
    faster = {"rows": [["ref.gather", 50.0, ""], ["hot.decode", 60.0, ""]]}
    _, failures = cb.compare(base, faster, threshold=1.3,
                             calibrate="ref.gather")
    assert failures == []                   # 1.2x <= 1.3x despite 0.5x ref
    # missing calibration row is itself a failure
    _, failures = cb.compare({"rows": [["hot.decode", 50.0, ""]]},
                             faster, threshold=1.3, calibrate="ref.gather")
    assert failures and "calibration" in failures[0]


def test_check_bench_errored_module_fails():
    cb = _load_check_bench()
    cur = {"rows": [["hot.gather", 100.0, ""], ["hot.decode", 50.0, ""],
                    ["analytic.row", 0.0, ""],
                    ["table9.ERROR", 0.0, "ValueError: boom"]]}
    _, failures = cb.compare(BASE, cur, threshold=1.3)
    assert failures and "errored" in failures[0]


# ------------------------------------------------------- metrics doc gate

def _metrics_doc():
    from repro import obs

    return obs.metrics_doc()


def test_validate_summary_checks_metrics_doc_when_present():
    rows = [["x", 1.5, "d"]]
    validate_summary({"rows": rows})                      # still optional
    validate_summary({"rows": rows, "metrics": _metrics_doc()})
    with pytest.raises(ValueError, match="'metrics' doc invalid"):
        validate_summary({"rows": rows, "metrics": {"schema": "bogus"}})


def test_check_bench_requires_metrics_doc_once_baseline_tracks_one(tmp_path):
    cb = _load_check_bench()
    base = {"rows": BASE["rows"], "metrics": _metrics_doc()}
    cur_ok = {"rows": BASE["rows"], "metrics": _metrics_doc()}
    _, failures = cb.compare(base, cur_ok, threshold=1.3)
    assert failures == []
    cur_missing = {"rows": BASE["rows"]}
    _, failures = cb.compare(base, cur_missing, threshold=1.3)
    assert failures and "metrics" in failures[0]
    # a baseline without one never demands it (pre-refresh compatibility)
    _, failures = cb.compare(BASE, cur_missing, threshold=1.3)
    assert failures == []
    # end-to-end: a schema-invalid doc is rejected at load time
    base_p = tmp_path / "base.json"
    cur_p = tmp_path / "cur.json"
    base_p.write_text(json.dumps(base))
    cur_p.write_text(json.dumps(
        {"rows": BASE["rows"], "metrics": {"schema": "bogus"}}
    ))
    assert cb.main([str(cur_p), "--baseline", str(base_p)]) == 1


def test_committed_baseline_tracks_obs_rows_and_metrics_doc():
    """The refreshed baseline carries the observability additions: the
    serving metrics-overhead row, the table5 utilisation rows, and a
    schema-valid `metrics` doc — so CI gates on all three."""
    from repro.obs.export import validate_metrics_doc

    with open(os.path.join(REPO, "benchmarks", "baseline.json")) as f:
        baseline = json.load(f)
    validate_summary(baseline)
    names = {r[0] for r in baseline["rows"]}
    assert "serving_obs_load0" in names
    assert {"table5.util_dead_frac", "table5.util_hot10_mass",
            "table5.util_cold_frac"} <= names
    validate_metrics_doc(baseline["metrics"])


@pytest.mark.slow
def test_table8_emits_obs_overhead_row():
    from benchmarks import table8_serving
    from repro import obs

    rows = table8_serving.run(smoke=True)
    assert not obs.enabled()    # the bench restores the disabled default
    row = next(r for r in rows if r[0] == "serving_obs_load0")
    assert row[1] > 0
    assert "overhead_x=" in row[2]
