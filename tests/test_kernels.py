"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import indexing
from repro.kernels import e8_lookup, gather_interp, ops, ref

SPEC = indexing.choose_torus(16)


def test_sort_network_is_a_sorting_network():
    """0-1 principle: a comparator network sorts all inputs iff it sorts
    every binary sequence. 2^8 = 256 cases, exhaustive."""
    bits = np.array(
        list(itertools.product([0.0, 1.0], repeat=8)), dtype=np.float32
    ).T  # (8, 256)
    keys, _ = e8_lookup._sort_rows_desc(jnp.asarray(bits), [jnp.asarray(bits)])
    keys = np.asarray(keys)
    assert np.all(np.diff(keys, axis=0) <= 0), "network failed to sort"


def test_sort_network_tracks_permutation(rng):
    x = rng.normal(size=(8, 50)).astype(np.float32)
    iota = np.broadcast_to(np.arange(8)[:, None], (8, 50)).astype(np.int32)
    keys, (vals, perm) = e8_lookup._sort_rows_desc(
        jnp.asarray(np.abs(x)), [jnp.asarray(x), jnp.asarray(iota)]
    )
    keys, vals, perm = map(np.asarray, (keys, vals, perm))
    for b in range(50):
        np.testing.assert_allclose(vals[:, b], x[perm[:, b], b])
        np.testing.assert_allclose(keys[:, b], np.abs(x[perm[:, b], b]))


@pytest.mark.slow
@pytest.mark.parametrize("n_queries", [1, 5, 128, 200])
@pytest.mark.parametrize("top_k", [8, 32])
def test_query_kernel_matches_ref(rng, n_queries, top_k):
    q = rng.uniform(-4, 12, size=(n_queries, 8)).astype(np.float32)
    idx_p, w_p = e8_lookup.lram_query_pallas(
        jnp.asarray(q), SPEC, top_k, interpret=True
    )
    idx_r, w_r = ref.lram_query_ref(jnp.asarray(q), SPEC, top_k)
    # weights as multisets (ties can reorder equal weights)
    np.testing.assert_allclose(
        np.sort(np.asarray(w_p), axis=-1),
        np.sort(np.asarray(w_r), axis=-1),
        atol=1e-5,
    )
    # interpolation result identical through a fixed table
    values = rng.normal(size=(SPEC.num_locations, 16)).astype(np.float32)
    out_p = ref.gather_interp_ref(jnp.asarray(values), idx_p, w_p)
    out_r = ref.gather_interp_ref(jnp.asarray(values), idx_r, w_r)
    np.testing.assert_allclose(
        np.asarray(out_p), np.asarray(out_r), rtol=2e-5, atol=1e-5
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m", [8, 64])
def test_gather_kernel_matches_ref(rng, dtype, m):
    values = jnp.asarray(
        rng.normal(size=(1024, m)).astype(np.float32)
    ).astype(dtype)
    idx = jnp.asarray(rng.integers(0, 1024, size=(17, 32)).astype(np.int32))
    w = jnp.asarray(rng.uniform(0, 1, size=(17, 32)).astype(np.float32))
    out_p = gather_interp.gather_interp_pallas(values, idx, w, interpret=True)
    out_r = ref.gather_interp_ref(values, idx, w)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out_p), np.asarray(out_r, dtype=np.float32), rtol=tol,
        atol=tol,
    )


@pytest.mark.slow
def test_query_kernel_batched_leading_dims(rng):
    q = rng.uniform(0, 8, size=(3, 4, 8)).astype(np.float32)
    idx, w = e8_lookup.lram_query_pallas(jnp.asarray(q), SPEC, interpret=True)
    assert idx.shape == (3, 4, 32) and w.shape == (3, 4, 32)


@pytest.mark.slow
def test_fused_lookup_grads_match_autodiff(rng):
    values = jnp.asarray(
        rng.normal(size=(SPEC.num_locations, 8)).astype(np.float32)
    )
    q = jnp.asarray(rng.uniform(0, 8, size=(40, 8)).astype(np.float32))

    def loss_pallas(v, qq):
        return jnp.sum(ops.lram_lookup(v, qq, SPEC, 32, True, True) ** 2)

    def loss_ref(v, qq):
        return jnp.sum(ref.lookup_ref(v, qq, SPEC, 32) ** 2)

    out_p = ops.lram_lookup(values, q, SPEC, 32, True, True)
    out_r = ref.lookup_ref(values, q, SPEC, 32)
    np.testing.assert_allclose(
        np.asarray(out_p), np.asarray(out_r), rtol=1e-5, atol=1e-5
    )
    gv, gq = jax.grad(loss_pallas, argnums=(0, 1))(values, q)
    gv_r, gq_r = jax.grad(loss_ref, argnums=(0, 1))(values, q)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(gv_r), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(gq), np.asarray(gq_r), rtol=1e-4, atol=1e-4
    )


@pytest.mark.slow
def test_fused_lookup_interpolation_property(rng):
    """phi(k) = v_k through the full Pallas path."""
    values = jnp.asarray(
        rng.normal(size=(SPEC.num_locations, 8)).astype(np.float32)
    )
    targets = np.array([7, 999, 2**15], dtype=np.int64)
    pts = indexing.decode_index(targets, SPEC).astype(np.float32)
    out = ops.lram_lookup(values, jnp.asarray(pts), SPEC, 32, True, True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(values)[targets], atol=1e-5
    )


def test_nearest_image_delta():
    q = jnp.asarray(np.array([[0.5] * 8], dtype=np.float32))
    k = jnp.asarray(np.array([[7.5, 0.5, 0.5, 0.5, 0.5, 0.5, 0.5, 0.5]],
                             dtype=np.float32))
    d = ops._nearest_image_delta(q, k, (8,) * 8)
    np.testing.assert_allclose(
        np.asarray(d)[0], [1.0, 0, 0, 0, 0, 0, 0, 0], atol=1e-6
    )
