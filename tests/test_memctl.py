"""The memory lifecycle manager (`repro.memctl`): telemetry counters,
online growth (append-only, exact at pre-growth points for every storage
kind, eager + jit + grad), live plan-to-plan migration (round-trip exact),
the controller's train-step and serve-tick policy loops, and the
plan-driven sharding rules that replaced the memory-table regex."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs, memctl, quant
from repro.core import indexing, lookup, lram
from repro.distributed import context as _ctx, sharding
from repro.distributed.sharded_lram import ShardedTieredStore
from repro.memstore import TieredSpec, TieredValueStore
from repro.models import transformer

KEY = jax.random.PRNGKey(0)
KW = dict(log2_locations=16, m=8, heads=2, query_norm="rms")

GROW_CELLS = [
    (p, s)
    for p in ("dense", "tiered", "sharded-tiered")
    for s in ("fp32", "int8", "fp8")
]


def make_cfg(placement, storage, **extra):
    kw = dict(KW, **extra)
    kw["table_quant"] = "none" if storage == "fp32" else storage
    if placement == "dense":
        return lram.LRAMConfig(interp_impl="reference", **kw)
    if placement == "tiered":
        kw.setdefault("tiered", TieredSpec(shard_rows=4096, cache_slots=4))
        return lram.LRAMConfig(interp_impl="tiered", **kw)
    kw.setdefault("tiered", TieredSpec(shard_rows=2048, cache_slots=2))
    kw.setdefault("model_shards", 4)
    return lram.LRAMConfig(interp_impl="sharded-tiered", **kw)


# ---------------------------------------------------------------------------
# the growth math: index preservation and the coarse-lattice parent rule
# ---------------------------------------------------------------------------

def test_grow_torus_preserves_old_indices():
    old = indexing.choose_torus(16)
    new = indexing.grow_torus(old, 2)
    assert new.num_locations == 2 * old.num_locations
    ids = np.arange(old.num_locations)
    pts = indexing.decode_index(ids, old)
    np.testing.assert_array_equal(
        np.asarray(indexing.encode_points(jnp.asarray(pts), new)), ids
    )


def test_growth_parents_is_alias_rule():
    """For K_0 enlargements, the lattice-derived parent mapping reduces to
    j mod old_N (the grown table is an alias stack of the old one)."""
    old = indexing.choose_torus(16)
    for factor in (2, 4):
        new = indexing.grow_torus(old, factor)
        n_old, n_new = old.num_locations, new.num_locations
        parents = indexing.growth_parents(old, new, n_old, n_new)
        np.testing.assert_array_equal(
            parents, np.arange(n_old, n_new) % n_old
        )


def test_grow_torus_rejects_bad_factor():
    spec = indexing.choose_torus(16)
    with pytest.raises(ValueError, match="power of two"):
        indexing.grow_torus(spec, 3)
    with pytest.raises(ValueError, match="multiples"):
        indexing.growth_parents(indexing.grow_torus(spec, 2), spec, 0, 1)


def test_lram_config_torus_override_validated():
    spec = indexing.grow_torus(indexing.choose_torus(16), 2)
    cfg = lram.LRAMConfig(**dict(KW, log2_locations=17), torus=spec)
    assert cfg.torus_spec == spec
    with pytest.raises(ValueError, match="locations"):
        lram.LRAMConfig(**KW, torus=spec)  # 2^17 torus vs log2=16


# ---------------------------------------------------------------------------
# growth equivalence: every placement x storage, eager + jit + grad
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("placement,storage", GROW_CELLS)
def test_grow_reproduces_pre_growth_points(placement, storage, rng):
    """After grow(N -> 2N), lookups at pre-growth *points* (the same
    geometric query positions, re-encoded on the grown torus) match the
    pre-growth outputs to float rounding — for every storage kind, the
    appended rows are bit-copies of their coarse-lattice parents."""
    cfg = make_cfg(placement, storage)
    params, state = lram.lram_init(KEY, cfg)
    plan = lookup.resolve(cfg)
    q = jnp.asarray(rng.uniform(0, 8, size=(16, 8)).astype(np.float32))
    idx_o, w = lram.indices_and_weights(q, cfg.torus_spec, cfg.top_k)
    y_pre = np.asarray(plan.interp(params["values"], idx_o, w))
    g_pre = np.asarray(jax.grad(
        lambda ww: jnp.sum(plan.interp(params["values"], idx_o, ww) ** 2)
    )(w))

    params2, cfg2 = memctl.grow(params, cfg, 2 ** 17)
    assert cfg2.num_locations == 2 ** 17
    plan2 = lookup.resolve(cfg2)
    idx_n, w_n = lram.indices_and_weights(q, cfg2.torus_spec, cfg2.top_k)
    np.testing.assert_array_equal(np.asarray(w_n), np.asarray(w))

    y_post = np.asarray(plan2.interp(params2["values"], idx_n, w))
    y_jit = np.asarray(jax.jit(
        lambda i, ww: plan2.interp(params2["values"], i, ww)
    )(idx_n, w))
    g_post = np.asarray(jax.grad(
        lambda ww: jnp.sum(plan2.interp(params2["values"], idx_n, ww) ** 2)
    )(w))
    np.testing.assert_allclose(y_post, y_pre, atol=1e-6)
    np.testing.assert_allclose(y_jit, y_pre, atol=1e-6)
    np.testing.assert_allclose(g_post, g_pre, atol=1e-5)


def test_grow_rejects_bad_sizes_and_sharded():
    cfg = make_cfg("dense", "fp32")
    params, _ = lram.lram_init(KEY, cfg)
    with pytest.raises(ValueError, match="multiple"):
        memctl.grow(params, cfg, 2 ** 16 + 4096)
    with pytest.raises(ValueError, match="grow"):
        memctl.grow(params, cfg, 2 ** 15)
    mesh = jax.make_mesh((1,), ("model",))
    _ctx.set_mesh(mesh)
    try:
        cfg_sh = lram.LRAMConfig(**KW, interp_impl="sharded")
        p_sh, _ = lram.lram_init(KEY, cfg_sh)
        with pytest.raises(lookup.LookupPlanError, match="grow"):
            memctl.grow(p_sh, cfg_sh, 2 ** 17)
    finally:
        _ctx.set_mesh(None)


def test_tiered_grow_appends_without_touching_cache(rng):
    """Growth appends host shards in place: the device cache keeps its
    residency (no invalidation, no new fills) and old shard ids stay
    valid; post-growth lookups of old rows are bit-identical."""
    cfg = make_cfg("tiered", "fp32")
    params, _ = lram.lram_init(KEY, cfg)
    store = params["values"]
    assert isinstance(store, TieredValueStore)
    idx = rng.integers(0, 2 ** 16, size=(8, 4)).astype(np.int32)
    w = rng.normal(size=idx.shape).astype(np.float32)
    y_pre = np.asarray(store.gather(idx, w))
    resident = store.resident_shards()
    fills = store.stats["fills"]

    params2, cfg2 = memctl.grow(params, cfg, 2 ** 17)
    assert params2["values"] is store  # in place: handles stay valid
    assert store.num_rows == 2 ** 17
    assert store.resident_shards() == resident
    assert store.stats["fills"] == fills
    np.testing.assert_array_equal(np.asarray(store.gather(idx, w)), y_pre)
    # appended rows alias their parents (j mod old_N)
    hi = idx + 2 ** 16
    np.testing.assert_array_equal(np.asarray(store.gather(hi, w)), y_pre)


def test_tiered_grow_trains_after_growth(rng):
    """Write-back still lands after growth — including into appended rows
    — and dirty state flushes through the grown host tier."""
    from repro import memstore

    cfg = make_cfg("tiered", "fp32")
    params, _ = lram.lram_init(KEY, cfg)
    store = params["values"]
    _, cfg2 = memctl.grow(params, cfg, 2 ** 17)
    store.writeback_lr = 0.1
    idx = rng.integers(0, 2 ** 17, size=(16, 4)).astype(np.int32)
    w = jnp.asarray(rng.normal(size=idx.shape).astype(np.float32))
    before = store.to_dense()

    def loss(w_):
        return jnp.sum(memstore.tiered_interp(store, jnp.asarray(idx), w_)
                       ** 2)

    jax.grad(loss)(w)
    after = store.to_dense()
    touched = np.zeros(2 ** 17, bool)
    touched[idx.reshape(-1)] = True
    assert not np.allclose(after[touched], before[touched])
    np.testing.assert_array_equal(after[~touched], before[~touched])


def test_sharded_tiered_grow_appends_ranges(rng):
    cfg = make_cfg("sharded-tiered", "fp32")
    params, _ = lram.lram_init(KEY, cfg)
    store = params["values"]
    assert isinstance(store, ShardedTieredStore)
    store.writeback_lr = 0.25
    before = store.to_dense()
    params2, cfg2 = memctl.grow(params, cfg, 2 ** 17)
    assert params2["values"] is store
    assert store.num_ranges == 8 and cfg2.model_shards == 8
    assert all(p.writeback_lr == 0.25 for p in store.parts)
    after = store.to_dense()
    np.testing.assert_array_equal(after[:2 ** 16], before)
    np.testing.assert_array_equal(after[2 ** 16:], before)  # alias copy


def test_grow_model_with_opt_state():
    """Model-level growth: every lram/values leaf grows (params + Adam
    moments, parent-copied), per-feature leaves stay, and the returned
    config re-resolves cleanly."""
    from repro import optim

    cfg = configs.get_smoke_config("lram-tiered")
    cfg = dataclasses.replace(
        cfg, lram=dataclasses.replace(cfg.lram, interp_impl="reference",
                                      tiered=None)
    )
    params, state = transformer.init(KEY, cfg)
    opt = optim.adam_init(params)
    n_old = cfg.lram.num_locations

    params2, cfg2, opt2 = memctl.grow_model(params, cfg, 2 * n_old,
                                            opt_state=opt)
    assert cfg2.lram.num_locations == 2 * n_old
    vals = [leaf for path, leaf
            in jax.tree_util.tree_flatten_with_path(params2)[0]
            if "values" in str(path)]
    assert vals and all(v.shape[0] == 2 * n_old for v in vals)
    mus = [leaf for path, leaf
           in jax.tree_util.tree_flatten_with_path(opt2["mu"])[0]
           if "values" in str(path)]
    assert mus and all(m.shape[0] == 2 * n_old for m in mus)
    # logits at pre-growth points: the grown model must still run
    toks = {"tokens": jnp.zeros((2, 8), jnp.int32)}
    logits, _, _ = transformer.forward(params2, state, toks, cfg2)
    assert np.isfinite(np.asarray(logits)).all()


# ---------------------------------------------------------------------------
# migration: dense <-> tiered <-> sharded-tiered, any storage pair
# ---------------------------------------------------------------------------

def test_migration_roundtrip_exact_model_logits():
    """Acceptance: dense -> tiered -> sharded-tiered -> dense reproduces
    logits exactly (fp32 payload moves, never re-encoded)."""
    cfg_d = dataclasses.replace(
        configs.get_smoke_config("lram-tiered"),
        lram=dataclasses.replace(
            configs.get_smoke_config("lram-tiered").lram,
            interp_impl="reference", tiered=None,
        ),
    )
    params, state = transformer.init(KEY, cfg_d)
    toks = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg_d.vocab_size, (2, 8)),
        jnp.int32)}
    y0 = np.asarray(transformer.forward(params, state, toks, cfg_d)[0])

    lram_t = dataclasses.replace(
        cfg_d.lram, interp_impl="tiered",
        tiered=TieredSpec(shard_rows=2048, cache_slots=4),
    )
    params, cfg_t = memctl.migrate_model(params, cfg_d, lram_t)
    y1 = np.asarray(transformer.forward(params, state, toks, cfg_t)[0])
    np.testing.assert_allclose(y1, y0, atol=1e-5)

    lram_st = dataclasses.replace(
        cfg_d.lram, interp_impl="sharded-tiered", model_shards=2,
        tiered=TieredSpec(shard_rows=2048, cache_slots=2),
    )
    params, cfg_st = memctl.migrate_model(params, cfg_t, lram_st)
    y2 = np.asarray(transformer.forward(params, state, toks, cfg_st)[0])
    np.testing.assert_allclose(y2, y0, atol=1e-5)

    params, cfg_back = memctl.migrate_model(params, cfg_st, cfg_d.lram)
    y3 = np.asarray(transformer.forward(params, state, toks, cfg_back)[0])
    np.testing.assert_array_equal(y3, y0)


def test_migration_same_kind_quant_payload_exact():
    """int8 -> int8 across placements moves payload + scales verbatim —
    no requantization drift, bit-equal dequantized tables."""
    cfg_dq = make_cfg("dense", "int8")
    params, _ = lram.lram_init(KEY, cfg_dq)
    table = params["values"]
    assert isinstance(table, quant.QuantizedTable)
    cfg_tq = make_cfg("tiered", "int8")
    p_t = memctl.migrate(params, cfg_dq, cfg_tq)
    store = p_t["values"]
    np.testing.assert_array_equal(
        store.to_dense(), np.asarray(table.dequantize())
    )
    # and back: payload survives a full cycle bit-exact
    p_d = memctl.migrate(p_t, cfg_tq, cfg_dq)
    np.testing.assert_array_equal(np.asarray(p_d["values"].q),
                                  np.asarray(table.q))
    np.testing.assert_array_equal(np.asarray(p_d["values"].scale),
                                  np.asarray(table.scale))


def test_migration_cross_storage_within_bound(rng):
    cfg_d = make_cfg("dense", "fp32")
    params, _ = lram.lram_init(KEY, cfg_d)
    dense = np.asarray(params["values"])
    cfg_q = make_cfg("sharded-tiered", "int8", model_shards=2)
    p_q = memctl.migrate(params, cfg_d, cfg_q)
    got = p_q["values"].to_dense()
    _, scale = quant.quantize_rows_np(dense, "int8")
    assert np.abs(got - dense).max() <= float(scale.max()) * 0.5 + 1e-7


def test_migration_rejects_mesh_and_resize():
    cfg = make_cfg("dense", "fp32")
    params, _ = lram.lram_init(KEY, cfg)
    mesh = jax.make_mesh((1,), ("model",))
    _ctx.set_mesh(mesh)
    try:
        with pytest.raises(lookup.LookupPlanError, match="migrate"):
            memctl.migrate(params, cfg,
                           lram.LRAMConfig(**KW, interp_impl="sharded"))
    finally:
        _ctx.set_mesh(None)
    with pytest.raises(ValueError, match="shape"):
        memctl.migrate(params, cfg,
                       make_cfg("tiered", "fp32", log2_locations=17))


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_telemetry_update_is_jit_safe_segment_sum(rng):
    tel = memctl.telemetry_init(1024, rows_per_bin=4)
    idx = rng.integers(0, 1024, size=(7, 5)).astype(np.int32)
    tel = jax.jit(memctl.telemetry_update)(tel, jnp.asarray(idx))
    counts = np.asarray(tel["counts"])
    want = np.bincount(idx.reshape(-1) // 4, minlength=256)
    np.testing.assert_array_equal(counts, want.astype(np.float32))
    assert int(tel["steps"]) == 1
    # second step decays the EMA toward the new hit vector
    tel2 = memctl.telemetry_update(tel, jnp.asarray(idx[:1]))
    assert float(np.asarray(tel2["ema"]).sum()) < float(counts.sum())


def test_utilisation_report_fractions():
    tel = memctl.telemetry_init(100, rows_per_bin=1)
    tel = memctl.telemetry_update(
        tel, jnp.asarray(np.arange(50, dtype=np.int32))
    )
    rows = memctl.utilisation_report(tel, prefix="t")
    byname = {r[0]: r[2] for r in rows}
    assert byname["t_dead_frac"].startswith("0.5000")
    from benchmarks.run import validate_summary

    validate_summary({"rows": rows})  # bench row schema


def test_store_telemetry_counts_accesses(rng):
    dense = rng.normal(size=(4096, 8)).astype(np.float32)
    store = ShardedTieredStore.from_dense(
        dense, TieredSpec(shard_rows=256, cache_slots=2), num_ranges=2
    )
    idx = rng.integers(0, 4096, size=(32, 4)).astype(np.int32)
    store.gather(idx, rng.normal(size=idx.shape).astype(np.float32))
    tel = memctl.store_telemetry(store)
    counts = np.asarray(tel["counts"])
    assert counts.shape == (16,) and int(tel["rows_per_bin"]) == 256
    want = np.bincount(idx.reshape(-1) >> 8, minlength=16)
    np.testing.assert_array_equal(counts, want.astype(np.float32))
    plan = lookup.resolve(make_cfg("sharded-tiered", "fp32"))
    assert plan.row_stats


def test_grow_telemetry_appends_dead_bins():
    tel = memctl.telemetry_init(512, rows_per_bin=8)
    tel = memctl.telemetry_update(
        tel, jnp.asarray(np.arange(512, dtype=np.int32))
    )
    tel2 = memctl.grow_telemetry(tel, 1024)
    counts = np.asarray(tel2["counts"])
    assert counts.shape == (128,)
    assert (counts[64:] == 0).all() and (counts[:64] > 0).all()


# ---------------------------------------------------------------------------
# the controller: train-step schedule and serve-tick spill
# ---------------------------------------------------------------------------

def test_parse_grow_at():
    assert memctl.parse_grow_at("10:17,20:18") == ((10, 17), (20, 18))
    with pytest.raises(ValueError, match="STEP:NEW_LOG2"):
        memctl.parse_grow_at("10")
    with pytest.raises(ValueError, match="increase"):
        memctl.parse_grow_at("10:18,20:17")
    with pytest.raises(ValueError, match="distinct"):
        memctl.parse_grow_at("10:17,10:18")


def test_controller_grows_on_schedule_once():
    cfg = configs.get_smoke_config("lram-tiered")
    params, _ = transformer.init(KEY, cfg)
    ctl = memctl.MemoryController(memctl.LifecyclePolicy(
        grow_at=memctl.parse_grow_at("2:17")
    ))
    n0 = cfg.lram.num_locations
    params, cfg, _, changed = ctl.on_train_step(0, params, cfg)
    assert not changed and cfg.lram.num_locations == n0
    params, cfg, _, changed = ctl.on_train_step(2, params, cfg)
    assert changed and cfg.lram.num_locations == 2 ** 17
    params, cfg, _, changed = ctl.on_train_step(2, params, cfg)
    assert not changed  # fires exactly once
    assert ctl.events and ctl.events[0]["event"] == "grow"


def test_controller_catch_up_applies_past_growths():
    cfg = configs.get_smoke_config("lram-tiered")
    params, _ = transformer.init(KEY, cfg)
    ctl = memctl.MemoryController(memctl.LifecyclePolicy(
        grow_at=memctl.parse_grow_at("1:17,5:18")
    ))
    params, cfg, _, changed = ctl.catch_up(3, params, cfg)
    assert changed and cfg.lram.num_locations == 2 ** 17  # only step-1 event


def test_engine_live_spill_preserves_generation():
    """The serve-tick spill (dense -> tiered mid-trace) must not change a
    single generated token: fp32 payload moves exactly and in-flight
    slots ride through the swap."""
    from repro.serving import EngineConfig, ServeEngine, synthetic_trace

    cfg = configs.get_smoke_config("lram-tiered")
    cfg = dataclasses.replace(
        cfg, lram=dataclasses.replace(cfg.lram, interp_impl="reference",
                                      tiered=None)
    )
    params, state = transformer.init(KEY, cfg)
    trace = synthetic_trace(np.random.default_rng(0), 4,
                            vocab_size=cfg.vocab_size, max_prompt=6,
                            max_gen=6)
    base = ServeEngine(params, state, cfg, EngineConfig(slots=2, max_len=16))
    want = {r.id: r.tokens for r in base.run(trace).requests}

    ctl = memctl.MemoryController(memctl.LifecyclePolicy(spill_at_tick=2))
    engine = ServeEngine(params, state, cfg,
                         EngineConfig(slots=2, max_len=16), controller=ctl)
    report = engine.run(trace)
    assert ctl.events and ctl.events[0]["event"] == "spill"
    assert engine.cfg.lram.interp_impl == "tiered"
    assert engine.stores  # prefetch handles discovered post-swap
    got = {r.id: r.tokens for r in report.requests}
    assert got == want


def test_controller_hbm_budget_trigger():
    cfg = configs.get_smoke_config("lram-tiered")
    cfg = dataclasses.replace(
        cfg, lram=dataclasses.replace(cfg.lram, interp_impl="reference",
                                      tiered=None)
    )
    table_bytes = cfg.lram.num_locations * cfg.lram.table_bytes_per_entry
    ctl = memctl.MemoryController(memctl.LifecyclePolicy(
        hbm_budget_bytes=table_bytes - 1
    ))

    class _Eng:  # the controller only reads cfg + ticks
        pass

    eng = _Eng()
    eng.cfg = cfg
    eng.ticks = 0
    assert ctl._spill_due(eng)
    ctl2 = memctl.MemoryController(memctl.LifecyclePolicy(
        hbm_budget_bytes=table_bytes + 1
    ))
    assert not ctl2._spill_due(eng)


# ---------------------------------------------------------------------------
# satellites: prefetch executor, plan-driven sharding rules
# ---------------------------------------------------------------------------

def test_sharded_tiered_prefetch_pool_matches_serial(rng):
    """The thread-pool prefetch warms exactly the shards the serial walk
    warmed, with identical fill/stat counting."""
    dense = rng.normal(size=(4096, 8)).astype(np.float32)
    spec = TieredSpec(shard_rows=256, cache_slots=2)
    a = ShardedTieredStore.from_dense(dense, spec, num_ranges=4)
    b = ShardedTieredStore.from_dense(dense, spec, num_ranges=4)
    idx = rng.integers(0, 4096, size=(64,)).astype(np.int32)
    for s in (a, b):
        s.gather_rows_host(idx)  # primes last_access per range
    a.prefetch_last()
    for part in b.parts:  # the old serial walk
        part.prefetch_last()
    assert a.resident_shards() == b.resident_shards()
    assert a.stats == b.stats
    a.prefetch(idx)  # the indexed variant fans out too
    assert a._pool is not None


def test_param_pspecs_plan_driven_memory_tables():
    """The resolved plan emits the memory table's pspec: replicated for
    dense placements, rows over `model` for the sharded placement — the
    regex rule for lram values is gone."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg_dense = configs.get_smoke_config("lram-tiered")
    cfg_dense = dataclasses.replace(
        cfg_dense, lram=dataclasses.replace(cfg_dense.lram,
                                            interp_impl="reference",
                                            tiered=None)
    )
    params, _ = transformer.init(KEY, cfg_dense)
    specs = sharding.param_pspecs(params, mesh, model_cfg=cfg_dense)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    vals = [s for path, s in flat if "values" in str(path)]
    assert vals and all(s == P() for s in vals)

    _ctx.set_mesh(mesh)
    try:
        cfg_sh = dataclasses.replace(
            cfg_dense, lram=dataclasses.replace(cfg_dense.lram,
                                                interp_impl="sharded")
        )
        params_sh, _ = transformer.init(KEY, cfg_sh)
        specs_sh = sharding.param_pspecs(params_sh, mesh, model_cfg=cfg_sh)
    finally:
        _ctx.set_mesh(None)
    flat_sh = jax.tree_util.tree_flatten_with_path(specs_sh)[0]
    vals_sh = [s for path, s in flat_sh if "values" in str(path)]
    assert vals_sh and all(s == P("model", None) for s in vals_sh)
    # no regex rule for lram values remains
    import re

    from repro.distributed.sharding import _rules, MeshAxes

    for pat, _spec in _rules(MeshAxes()):
        assert not re.search(pat, "x/memffn/lram/values") or pat == r".*", pat


# ---------------------------------------------------------------------------
# e2e: train with a mid-run growth step, resume, then serve the checkpoint
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_grow_resume_serve_e2e(tmp_path):
    """Acceptance: an lram-tiered model trained with one mid-run --grow-at
    growth step trains end to end, a relaunch catches up past growths
    before restoring (grow-on-restore shapes line up), and the grown
    checkpoint serves via --grow-to."""
    import textwrap

    from conftest import run_in_subprocess

    ckpt = str(tmp_path / "ckpt")
    out = run_in_subprocess(textwrap.dedent(f"""
        from repro.launch import train
        train.main(["--arch", "lram-tiered", "--smoke", "--steps", "4",
                    "--batch", "2", "--seq", "16", "--grow-at", "2:17",
                    "--ckpt-dir", {ckpt!r}, "--ckpt-every", "2",
                    "--log-every", "1"])
    """), timeout=900)
    assert '"grow": "2^17"' in out

    # relaunch: catch_up re-applies the growth, restore resumes at step 4
    out2 = run_in_subprocess(textwrap.dedent(f"""
        from repro.launch import train
        train.main(["--arch", "lram-tiered", "--smoke", "--steps", "6",
                    "--batch", "2", "--seq", "16", "--grow-at", "2:17",
                    "--ckpt-dir", {ckpt!r}, "--ckpt-every", "100",
                    "--log-every", "1"])
    """), timeout=900)
    assert "resumed from step 4" in out2

    out3 = run_in_subprocess(textwrap.dedent(f"""
        from repro.launch import serve
        serve.main(["--arch", "lram-tiered", "--smoke", "--batch", "2",
                    "--prompt-len", "4", "--gen", "3", "--grow-to", "17",
                    "--ckpt-dir", {ckpt!r}, "--json"])
    """), timeout=900)
    assert '"restored_step"' in out3 and '"tokens_per_sec"' in out3
