"""Per-tenant memory overlays (`repro.serving.overlay` + the
`repro.core.overlay` pack protocol): overlay semantics property-tested
against pure-dict reference models under random op interleavings, tenant
isolation on the serve engine (empty overlay == no overlay, bit-exact;
mixed-tenant == each tenant alone), lifecycle enforcement that never
perturbs in-flight requests, spill/restore round trips, and the
zero-recompilation attach/detach guarantee."""

import collections
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypo import given, settings, st
from repro import configs, memctl, quant
from repro.core import lookup, lram
from repro.memstore import TieredSpec, TieredValueStore
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving import (
    EngineConfig,
    OverlayManager,
    Request,
    ServeEngine,
    TenantOverlay,
    synthetic_trace,
)

KEY = jax.random.PRNGKey(0)
KW = dict(log2_locations=16, m=8, heads=2, query_norm="rms")
STORAGES = ("fp32", "int8", "fp8")


def _roundtrip(v, storage):
    """What one overlay write stores: the base table's storage grid."""
    v = np.asarray(v, np.float32)
    if storage == "fp32":
        return v.copy()
    q, scale = quant.quantize_rows_np(v, storage)
    return quant.dequantize_rows_np(
        q[None], np.asarray([scale], np.float32)
    )[0]


def _row(seed, m=4):
    return np.random.default_rng(seed).normal(size=m).astype(np.float32)


# ---------------------------------------------------------------------------
# property: TenantOverlay == an OrderedDict reference model
# ---------------------------------------------------------------------------

class RefOverlay:
    """Pure-dict reference: per-layer row -> effective fp32 value, with
    insertion-order recency and evict-oldest beyond capacity."""

    def __init__(self, num_layers, m, storage, cap):
        self.m, self.storage, self.cap = m, storage, cap
        self.rows = [collections.OrderedDict() for _ in range(num_layers)]

    def write(self, layer, row, v):
        od = self.rows[layer]
        od.pop(row, None)
        od[row] = _roundtrip(v, self.storage)
        while len(od) > self.cap:
            od.popitem(last=False)

    def read(self, layer, row):
        return self.rows[layer].get(row)

    def evict(self, layer, row):
        return self.rows[layer].pop(row, None) is not None


def _assert_overlay_matches(ov: TenantOverlay, ref: RefOverlay):
    assert ov.num_rows == sum(len(od) for od in ref.rows)
    for layer, od in enumerate(ref.rows):
        assert ov.packed_rows(layer) == list(od), (
            f"layer {layer}: recency order diverged"
        )
        for row, want in od.items():
            got = ov.read(layer, row)
            np.testing.assert_array_equal(got, want)


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_tenant_overlay_matches_reference_model(data):
    """Random write/read/evict interleavings: the overlay's visible state
    (reads, row count, recency order) equals the reference model exactly,
    for every storage kind."""
    storage = data.draw(st.sampled_from(STORAGES))
    cap = data.draw(st.integers(min_value=1, max_value=4))
    layers = data.draw(st.integers(min_value=1, max_value=2))
    ops = data.draw(st.lists(
        st.tuples(
            st.sampled_from(["write", "read", "evict"]),
            st.integers(min_value=0, max_value=1),   # layer (mod layers)
            st.integers(min_value=0, max_value=7),   # row id
            st.integers(min_value=0, max_value=999),  # value seed
        ),
        max_size=50,
    ))
    ov = TenantOverlay("t", num_layers=layers, m=4, storage=storage,
                       max_rows=cap)
    ref = RefOverlay(layers, 4, storage, cap)
    for op, layer, row, seed in ops:
        layer %= layers
        if op == "write":
            v = _row(seed)
            ov.write(layer, row, v)
            ref.write(layer, row, v)
        elif op == "read":
            got, want = ov.read(layer, row), ref.read(layer, row)
            assert (got is None) == (want is None)
            if want is not None:
                np.testing.assert_array_equal(got, want)
        else:
            assert ov.evict(layer, row) == ref.evict(layer, row)
        _assert_overlay_matches(ov, ref)


@pytest.mark.parametrize("storage", STORAGES)
def test_tenant_overlay_save_load_roundtrip(storage, tmp_path):
    """npz persistence is lossless in storage form (fp8 payloads ride as
    uint8 views; scales and recency order survive)."""
    rng = np.random.default_rng(3)
    ov = TenantOverlay("u/1", num_layers=2, m=4, storage=storage,
                       max_rows=8)
    for i in range(12):
        ov.write(int(rng.integers(0, 2)), int(rng.integers(0, 16)),
                 rng.normal(size=4).astype(np.float32))
    ov.last_used_tick = 7
    path = str(tmp_path / "ov.npz")
    ov.save(path)
    back = TenantOverlay.load(path, m=4)
    assert back.tenant_id == "u/1" and back.storage == storage
    assert back.last_used_tick == 7 and back.writes == ov.writes
    for layer in range(2):
        assert back.packed_rows(layer) == ov.packed_rows(layer)
        for row in ov.packed_rows(layer):
            np.testing.assert_array_equal(back.read(layer, row),
                                          ov.read(layer, row))


# ---------------------------------------------------------------------------
# property: OverlayManager == a reference model under op interleavings
# ---------------------------------------------------------------------------

class _RefManager:
    """Reference semantics for attach/detach/writeback/enforce, built on
    RefOverlay + plain loops (vs the manager's vectorized paths)."""

    def __init__(self, base, storage, slots, cap, lr, spill_dir):
        self.base = base                      # (L, N, m) fp32
        self.L, _, self.m = base.shape
        self.storage, self.cap, self.lr = storage, cap, lr
        self.spill_dir = spill_dir
        self.slot_tenant = [None] * slots
        self.overlays = {}
        self.spilled = {}                     # tenant -> parked RefOverlay
        self.last_used = {}

    def _get(self, tid):
        if tid not in self.overlays:
            self.overlays[tid] = RefOverlay(self.L, self.m, self.storage,
                                            self.cap)
            self.last_used.setdefault(tid, 0)
        ov = self.overlays[tid]
        parked = self.spilled.pop(tid, None)
        if parked is not None and not any(len(od) for od in ov.rows):
            self.overlays[tid] = ov = parked
        return ov

    def attach(self, slot, tid, tick):
        self.detach(slot)
        if tid is None:
            return
        self._get(tid)
        self.last_used[tid] = max(self.last_used[tid], tick)
        self.slot_tenant[slot] = tid

    def detach(self, slot):
        self.slot_tenant[slot] = None

    def effective(self, tid, layer, row):
        got = self.overlays[tid].read(layer, row)
        return self.base[layer][row] if got is None else got

    def writeback(self, slot, idx, w, y, tick):
        tid = self.slot_tenant[slot]
        if tid is None:
            return
        ov = self.overlays[tid]
        for layer in range(self.L):
            flat = idx[layer].reshape(-1)
            k = idx[layer].shape[-1]
            agg = {}
            for i, r in enumerate(flat.tolist()):
                contrib = (w[layer].reshape(-1)[i]
                           * y[layer][i // k]).astype(np.float32)
                agg[r] = agg.get(r, np.zeros(self.m, np.float32)) + contrib
            # the manager aggregates over np.unique's sorted row order
            for r in sorted(agg):
                ov.write(layer, r, self.effective(tid, layer, r)
                         + self.lr * agg[r])
        self.last_used[tid] = max(self.last_used[tid], tick)

    def nbytes(self, tid):
        kind = None if self.storage == "fp32" else self.storage
        return (sum(len(od) for od in self.overlays[tid].rows)
                * quant.bytes_per_entry(self.m, kind))

    def enforce(self, tick, ttl, budget):
        attached = {t for t in self.slot_tenant if t is not None}

        def offload(tid):
            if self.spill_dir is not None:
                self.spilled[tid] = self.overlays[tid]
            self.overlays[tid] = RefOverlay(self.L, self.m, self.storage,
                                            self.cap)

        if ttl is not None:
            for tid in list(self.overlays):
                if tid in attached or self.nbytes(tid) == 0:
                    continue
                if tick - self.last_used[tid] >= ttl:
                    offload(tid)
        if budget is not None:
            total = sum(self.nbytes(t) for t in self.overlays)
            if total > budget:
                lru = sorted((self.last_used[t], t) for t in self.overlays
                             if t not in attached and self.nbytes(t) > 0)
                for _, tid in lru:
                    if total <= budget:
                        break
                    total -= self.nbytes(tid)
                    offload(tid)


def _assert_manager_matches(mgr: OverlayManager, ref: _RefManager):
    assert mgr.slot_tenant == ref.slot_tenant
    assert set(mgr.overlays) == set(ref.overlays)
    for tid, rov in ref.overlays.items():
        _assert_overlay_matches(mgr.overlays[tid], rov)
    # pack invariant: detached slots are inert; attached slots carry
    # exactly the tenant's rows with delta = effective - base
    for b, tid in enumerate(mgr.slot_tenant):
        if tid is None:
            assert (mgr.ids[:, b] == -1).all()
            assert (mgr.deltas[:, b] == 0.0).all()
            continue
        for layer in range(ref.L):
            packed = list(ref.overlays[tid].rows[layer])
            n = len(packed)
            assert mgr.ids[layer, b, :n].tolist() == packed
            assert (mgr.ids[layer, b, n:] == -1).all()
            for j, r in enumerate(packed):
                np.testing.assert_array_equal(
                    mgr.deltas[layer, b, j],
                    ref.effective(tid, layer, r) - ref.base[layer][r],
                )


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_manager_matches_reference_under_interleavings(data):
    """Random attach/detach/writeback/enforce interleavings: tenant rows,
    recency, per-slot packs (delta = effective - base), and
    spill-restore-on-attach all match the reference model exactly."""
    storage = data.draw(st.sampled_from(STORAGES))
    spill = data.draw(st.booleans())
    L, m, slots, cap, N, heads, k = 2, 4, 2, 3, 16, 2, 2
    rng = np.random.default_rng(
        data.draw(st.integers(min_value=0, max_value=2**31))
    )
    base = rng.normal(size=(L, N, m)).astype(np.float32)
    with tempfile.TemporaryDirectory() as tmp:
        spill_dir = tmp if spill else None
        mgr = OverlayManager(num_layers=L, m=m, storage=storage,
                             slots=slots, rows=cap, write_lr=0.5,
                             spill_dir=spill_dir)
        mgr.set_base_reader(
            lambda layer, rows: base[layer][np.asarray(rows, np.int64)]
        )
        ref = _RefManager(base, storage, slots, cap, 0.5, spill_dir)
        tick = 0
        for _ in range(data.draw(st.integers(min_value=1, max_value=30))):
            op = data.draw(st.sampled_from(
                ["attach", "detach", "writeback", "enforce", "tick"]
            ))
            if op == "tick":
                tick += data.draw(st.integers(min_value=1, max_value=3))
            elif op == "attach":
                slot = data.draw(st.integers(min_value=0,
                                             max_value=slots - 1))
                tid = data.draw(st.sampled_from(["A", "B", "C", None]))
                mgr.attach(slot, tid, tick=tick)
                ref.attach(slot, tid, tick)
            elif op == "detach":
                slot = data.draw(st.integers(min_value=0,
                                             max_value=slots - 1))
                mgr.detach(slot)
                ref.detach(slot)
            elif op == "writeback":
                slot = data.draw(st.integers(min_value=0,
                                             max_value=slots - 1))
                seed = data.draw(st.integers(min_value=0, max_value=999))
                r2 = np.random.default_rng(seed)
                idx = r2.integers(0, N, size=(L, heads, k))
                w = r2.normal(size=(L, heads, k)).astype(np.float32)
                y = r2.normal(size=(L, heads, m)).astype(np.float32)
                mgr.writeback(slot, idx, w, y, tick=tick)
                ref.writeback(slot, idx, w, y, tick)
            else:
                ttl = data.draw(st.sampled_from([None, 1, 3]))
                budget = data.draw(st.sampled_from([None, 0, 64]))
                mgr.enforce(tick=tick, ttl_ticks=ttl, budget_bytes=budget)
                ref.enforce(tick, ttl, budget)
            _assert_manager_matches(mgr, ref)


def test_enforce_never_touches_attached_tenants(tmp_path):
    """TTL expiry and budget pressure only offload *detached* tenants —
    an in-flight request keeps its overlay no matter the policy."""
    base = np.zeros((1, 8, 4), np.float32)
    mgr = OverlayManager(num_layers=1, m=4, storage="fp32", slots=2,
                         rows=4, spill_dir=str(tmp_path))
    mgr.set_base_reader(lambda layer, rows: base[layer][rows])
    mgr.attach(0, "inflight", tick=0)
    for tid in ("inflight", "idle"):
        mgr.get(tid).write(0, 3, np.ones(4, np.float32))
    events = mgr.enforce(tick=100, ttl_ticks=1, budget_bytes=0)
    assert [e["tenant"] for e in events] == ["idle"]
    assert events[0]["action"] == "spill"
    assert mgr.get("inflight").num_rows == 1
    assert mgr.overlays["idle"].num_rows == 0
    # the spilled tenant restores transparently on its next attach
    mgr.attach(1, "idle", tick=101)
    assert mgr.stats["restores"] == 1
    np.testing.assert_array_equal(mgr.get("idle").read(0, 3),
                                  np.ones(4, np.float32))


def test_enforce_without_spill_dir_drops(tmp_path):
    mgr = OverlayManager(num_layers=1, m=4, storage="fp32", slots=1,
                         rows=4)
    mgr.set_base_reader(lambda layer, rows: np.zeros((len(rows), 4),
                                                     np.float32))
    mgr.get("gone").write(0, 1, np.ones(4, np.float32))
    events = mgr.enforce(tick=9, ttl_ticks=1)
    assert events[0]["action"] == "drop" and mgr.stats["drops"] == 1
    mgr.attach(0, "gone", tick=10)
    assert mgr.get("gone").num_rows == 0  # nothing to restore


def test_manager_save_all_load_all_roundtrip(tmp_path):
    mgr = OverlayManager(num_layers=2, m=4, storage="int8", slots=1,
                         rows=4)
    mgr.set_base_reader(lambda layer, rows: np.zeros((len(rows), 4),
                                                     np.float32))
    rng = np.random.default_rng(0)
    for tid in ("a", "b/c"):
        for i in range(3):
            mgr.get(tid).write(i % 2, i, rng.normal(size=4))
    assert mgr.save_all(str(tmp_path)) == 2
    back = OverlayManager(num_layers=2, m=4, storage="int8", slots=1,
                          rows=4)
    assert back.load_all(str(tmp_path)) == 2
    for tid in ("a", "b/c"):
        _want, _got = mgr.overlays[tid], back.overlays[tid]
        for layer in range(2):
            assert _got.packed_rows(layer) == _want.packed_rows(layer)
            for r in _want.packed_rows(layer):
                np.testing.assert_array_equal(_got.read(layer, r),
                                              _want.read(layer, r))
    wrong = OverlayManager(num_layers=2, m=4, storage="fp8", slots=1,
                           rows=4)
    with pytest.raises(ValueError, match="expects"):
        wrong.load_all(str(tmp_path))


# ---------------------------------------------------------------------------
# plan capability: overlay support composes with placement x storage
# ---------------------------------------------------------------------------

def test_supports_overlay_capability_matrix():
    assert lookup.resolve(lram.LRAMConfig(**KW)).supports_overlay
    assert lookup.resolve(
        lram.LRAMConfig(**KW, table_quant="int8")
    ).supports_overlay
    tiered = lram.LRAMConfig(
        **KW, interp_impl="tiered", table_quant="fp8",
        tiered=TieredSpec(shard_rows=4096, cache_slots=4),
    )
    assert lookup.resolve(tiered).supports_overlay
    shti = lram.LRAMConfig(
        **KW, interp_impl="sharded-tiered", model_shards=4,
        tiered=TieredSpec(shard_rows=2048, cache_slots=2),
    )
    assert lookup.resolve(shti).supports_overlay
    mesh = jax.make_mesh((1,), ("model",))
    from repro.distributed import context as _ctx
    _ctx.set_mesh(mesh)
    try:
        sharded = lookup.resolve(lram.LRAMConfig(**KW,
                                                 interp_impl="sharded"))
    finally:
        _ctx.set_mesh(None)
    assert not sharded.supports_overlay  # mesh-resident rows: no host CoW


@pytest.mark.parametrize("storage", STORAGES)
def test_read_rows_fp32_matches_table_forms(storage, rng):
    """The base-row reader the overlay deltas are computed against agrees
    across the table's dense / quantized / tiered forms."""
    dense = rng.normal(size=(1024, 8)).astype(np.float32)
    rows = rng.integers(0, 1024, size=(16,))
    if storage == "fp32":
        want = dense[rows]
        got_dense = lookup.read_rows_fp32(jnp.asarray(dense), rows)
        store = TieredValueStore.from_dense(
            dense, TieredSpec(shard_rows=256, cache_slots=4)
        )
    else:
        qt = quant.QuantizedTable.from_dense(dense, storage)
        want = quant.dequantize_rows_np(np.asarray(qt.q)[rows],
                                        np.asarray(qt.scale)[rows])
        got_dense = lookup.read_rows_fp32(qt, rows)
        store = TieredValueStore.from_dense(
            dense, TieredSpec(shard_rows=256, cache_slots=4,
                              quant=storage)
        )
    got_store = lookup.read_rows_fp32(store, rows)
    np.testing.assert_array_equal(got_dense, want)
    np.testing.assert_allclose(got_store, want, atol=1e-6)


# ---------------------------------------------------------------------------
# the serve engine: tenant isolation, writeback, zero recompilation
# ---------------------------------------------------------------------------

def _tiny_cfg(**lram_kw):
    lram_kw.setdefault("query_norm", "rms")
    lram_kw.setdefault("interp_impl", "reference")
    return ModelConfig(
        name="tiny-overlay", family="dense", num_layers=2, d_model=32,
        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=97,
        objective="clm", remat=False, lram_layers=(1,),
        lram=lram.memffn_config(32, 16, **lram_kw),
    )


@pytest.fixture(scope="module")
def tiny_lram_model():
    cfg = _tiny_cfg()
    params, state = transformer.init(KEY, cfg)
    return cfg, params, state


def test_engine_rejects_overlay_without_memory_arch():
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params, state = transformer.init(KEY, cfg)
    with pytest.raises(ValueError, match="memory arch"):
        ServeEngine(params, state, cfg,
                    EngineConfig(slots=1, max_len=8, overlay_rows=4))


def test_empty_overlay_is_bit_exact_vs_no_overlay(tiny_lram_model):
    """An anonymous trace through an overlay-enabled engine produces
    bit-identical tokens AND logits to the overlay-disabled engine: the
    empty-pack correction is exactly zero, not merely small."""
    cfg, params, state = tiny_lram_model
    trace = synthetic_trace(np.random.default_rng(0), 4, vocab_size=97,
                            max_prompt=6, max_gen=5)
    plain = ServeEngine(params, state, cfg,
                        EngineConfig(slots=2, max_len=12)).run(trace)
    overlaid = ServeEngine(
        params, state, cfg,
        EngineConfig(slots=2, max_len=12, overlay_rows=4),
    ).run(trace)
    for a, b in zip(plain.requests, overlaid.requests):
        assert a.id == b.id and a.tokens == b.tokens
        np.testing.assert_array_equal(a.first_logits, b.first_logits)


def test_retire_frees_overlay_and_never_recompiles(tiny_lram_model):
    """Slot retirement detaches the tenant (packs zeroed, no leak) and the
    whole admit/attach/retire/detach cycle reuses ONE decode executable —
    the fixed-shape-pack guarantee."""
    cfg, params, state = tiny_lram_model
    trace = synthetic_trace(np.random.default_rng(1), 5, vocab_size=97,
                            max_prompt=6, max_gen=5, tenants=2)
    engine = ServeEngine(params, state, cfg,
                         EngineConfig(slots=2, max_len=12, overlay_rows=6))
    report = engine.run(trace)
    mgr = engine.overlays
    assert mgr.attached == 0
    assert (mgr.ids == -1).all() and (mgr.deltas == 0.0).all()
    assert mgr.stats["attaches"] == mgr.stats["detaches"] > 0
    assert mgr.stats["writebacks"] > 0
    assert engine._decode._cache_size() == 1
    # overlay telemetry rides the report rows + summary
    assert report.overlay is not None and report.overlay["tenants"] == 2
    assert any(r[0] == "serve_overlay" for r in report.rows())
    assert report.summary(cfg.name)["overlay"]["attaches"] > 0


def test_overlay_correction_reaches_decode_logits(tiny_lram_model):
    """Deterministic forced-hit probe: a pack whose ids cover the rows one
    decode step actually visits must move that step's logits; the same
    pack emptied must not."""
    cfg, params, state = tiny_lram_model
    engine = ServeEngine(params, state, cfg,
                         EngineConfig(slots=1, max_len=12, overlay_rows=8))
    tok = jnp.array([[5]], jnp.int32)
    pos = jnp.array([3], jnp.int32)
    empty_ids = jnp.asarray(np.full_like(engine.overlays.ids, -1))
    empty_deltas = jnp.asarray(np.zeros_like(engine.overlays.deltas))
    cache = transformer.init_cache(cfg, 1, 12)
    logits0, _, access = engine._decode(tok, pos, cache, empty_ids,
                                        empty_deltas)
    visited = np.unique(np.asarray(access[0])[0].reshape(-1))[:8]
    ids = np.full_like(engine.overlays.ids, -1)
    deltas = np.zeros_like(engine.overlays.deltas)
    ids[0, 0, :len(visited)] = visited
    deltas[0, 0, :len(visited)] = 5.0
    cache = transformer.init_cache(cfg, 1, 12)
    logits1, _, _ = engine._decode(tok, pos, cache, jnp.asarray(ids),
                                   jnp.asarray(deltas))
    assert not np.array_equal(np.asarray(logits1), np.asarray(logits0))


def test_writeback_pack_deltas_match_base_table(tiny_lram_model):
    """After serving one tenant, re-attaching them fills the pack with
    delta = dequant(overlay row) - base row, checked directly against the
    model's value table (not through the manager's own reader)."""
    cfg, params, state = tiny_lram_model
    engine = ServeEngine(params, state, cfg,
                         EngineConfig(slots=1, max_len=14, overlay_rows=32,
                                      overlay_write_lr=1.0))
    engine.run([Request(id=0, prompt=np.arange(1, 7, dtype=np.int32),
                        max_new_tokens=6, tenant_id="A")])
    ov = engine.overlays.get("A")
    assert ov.num_rows > 0 and ov.writes > 0
    engine.overlays.attach(0, "A", tick=99)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    values = [v for path, v in flat
              if "lram" in str(path) and "values" in str(path)]
    assert len(values) == 1
    base = np.asarray(values[0], np.float32)
    packed = ov.packed_rows(0)
    assert engine.overlays.ids[0, 0, :len(packed)].tolist() == packed
    for j, r in enumerate(packed):
        np.testing.assert_array_equal(
            engine.overlays.deltas[0, 0, j],
            ov.read(0, r) - base[r],
        )


@pytest.mark.slow
def test_mixed_tenants_match_each_tenant_alone(tiny_lram_model):
    """Acceptance: a mixed-tenant continuous-batching run produces
    per-tenant tokens AND first logits bit-identical to each tenant
    running alone against base + their overlay."""
    cfg, params, state = tiny_lram_model
    trace = synthetic_trace(np.random.default_rng(3), 4, vocab_size=97,
                            max_prompt=6, max_gen=6)
    for i, req in enumerate(trace):
        req.tenant_id = f"T{i}"
    ecfg = EngineConfig(slots=2, max_len=12, overlay_rows=6)
    mixed = ServeEngine(params, state, cfg, ecfg).run(trace)
    for req in trace:
        alone = ServeEngine(params, state, cfg, ecfg).run([req])
        got = next(r for r in mixed.requests if r.id == req.id)
        want = alone.requests[0]
        assert got.tokens == want.tokens
        np.testing.assert_array_equal(got.first_logits, want.first_logits)


@pytest.mark.slow
def test_overlay_on_quantized_table_engine(tiny_lram_model):
    """Overlay storage follows the plan's storage kind: an int8 base
    table gets int8 overlay rows, and the engine still runs end to end
    with stats accounted."""
    cfg = _tiny_cfg(table_quant="int8")
    params, state = transformer.init(KEY, cfg)
    trace = synthetic_trace(np.random.default_rng(4), 3, vocab_size=97,
                            max_prompt=5, max_gen=4, tenants=2)
    engine = ServeEngine(params, state, cfg,
                         EngineConfig(slots=2, max_len=10, overlay_rows=4))
    report = engine.run(trace)
    assert engine.overlays.storage == "int8"
    for ov in engine.overlays.overlays.values():
        for od in ov.rows:
            for payload, scale in od.values():
                assert payload.dtype == np.int8 and scale is not None
    assert report.overlay["writebacks"] > 0


# ---------------------------------------------------------------------------
# lifecycle: the controller's overlay tick never perturbs in-flight work
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("ttl,budget_kb", [(2, None), (None, 0.25),
                                           (1, 0.25)])
def test_controller_overlay_lifecycle_preserves_generation(
        tiny_lram_model, tmp_path, ttl, budget_kb):
    """Fuzzing the TTL/byte-budget schedule through MemoryController:
    overlays expire/spill/restore between ticks without changing a single
    generated token, because enforcement only offloads detached tenants
    and spill files restore losslessly on re-attach."""
    cfg, params, state = tiny_lram_model
    trace = synthetic_trace(np.random.default_rng(5), 6, vocab_size=97,
                            max_prompt=6, max_gen=6, tenants=2)
    ecfg = EngineConfig(slots=2, max_len=12, overlay_rows=6)
    want = {r.id: r.tokens for r in
            ServeEngine(params, state, cfg, ecfg).run(trace).requests}
    ctl = memctl.MemoryController(memctl.LifecyclePolicy(
        tenant_ttl_ticks=ttl,
        tenant_budget_bytes=(int(budget_kb * 1024)
                             if budget_kb is not None else None),
        overlay_spill_dir=str(tmp_path),
    ))
    engine = ServeEngine(params, state, cfg, ecfg, controller=ctl)
    got = {r.id: r.tokens for r in engine.run(trace).requests}
    assert got == want
    assert all(e["event"].startswith("overlay_") for e in ctl.events)
    assert all(e["action"] == "spill" for e in ctl.events)
    stats = engine.overlays.stats
    if ctl.events:
        assert stats["spills"] == len(ctl.events)


@pytest.mark.slow
def test_serve_cli_multitenant_e2e(tmp_path):
    """The serve CLI end to end: multi-tenant trace, overlay lifecycle
    flags, persistence across a relaunch."""
    from repro.launch import serve

    args = ["--smoke", "--batch", "2", "--prompt-len", "4", "--gen", "3",
            "--tenants", "2", "--overlay-rows", "6",
            "--overlay-ttl", "50", "--overlay-budget-kb", "64",
            "--overlay-dir", str(tmp_path / "ov")]
    report = serve.main(args)
    assert report.overlay is not None
    assert report.overlay["tenants"] >= 1
    saved = os.listdir(tmp_path / "ov")
    assert any(f.startswith("overlay_") and f.endswith(".npz")
               for f in saved)
    report2 = serve.main(args)  # relaunch restores the parked overlays
    assert report2.overlay["tenants"] >= 1
