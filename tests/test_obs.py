"""Observability layer (repro.obs): registry semantics, off-is-free,
device accumulate->drain under jit, span nesting, exporter schemas,
bit-exactness of the metrics-on serve path, HLO identity of the train
step, and thread-safety of the tiered store's stat counters."""

import concurrent.futures
import json
import math
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import importlib

from repro import configs, obs
from repro.memstore import TieredSpec, TieredValueStore
from repro.models import transformer
from repro.obs import export

# `obs.registry` the accessor shadows the submodule on the package
reg = importlib.import_module("repro.obs.registry")
from repro.serving import EngineConfig, ServeEngine, synthetic_trace


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with the process default: disabled."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_semantics():
    r = reg.MetricsRegistry()
    c = r.counter("c")
    c.inc()
    c.inc(2.5)
    assert c.get() == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = r.gauge("g")
    g.set(7)
    g.add(-2)
    assert g.get() == 5.0
    h = r.histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 3.0, 100.0):
        h.observe(v)
    snap = h.snapshot()
    # le semantics: 0.5->le1, 1.0->le1 (boundary counts in its bucket),
    # 3.0->le4, 100->+Inf
    assert snap["counts"] == [2, 0, 1, 1]
    assert snap["count"] == 4 and snap["sum"] == pytest.approx(104.5)
    assert h.quantile(0.5) == 1.0
    assert h.quantile(1.0) == math.inf


def test_registry_same_name_same_metric_kind_conflict_raises():
    r = reg.MetricsRegistry()
    assert r.counter("x") is r.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        r.gauge("x")


def test_histogram_rejects_bad_buckets_and_bad_drain():
    with pytest.raises(ValueError):
        reg.Histogram("h", buckets=())
    with pytest.raises(ValueError):
        reg.Histogram("h", buckets=(1.0, 1.0))
    h = reg.Histogram("h", buckets=(1.0, 2.0))
    with pytest.raises(ValueError, match="expected 3 bucket counts"):
        h.merge_counts([1, 2])


def test_disabled_registry_is_null_and_free():
    r = reg.MetricsRegistry(enabled=False)
    c = r.counter("c")
    assert c is reg.NULL_METRIC
    assert c is r.histogram("h")  # one shared singleton for every kind
    c.inc()
    c.observe(1.0)
    c.set(2.0)
    assert c.get() == 0.0
    assert r.snapshot() == {}
    # the process default is the disabled state
    assert not obs.enabled()
    assert obs.counter("anything") is reg.NULL_METRIC
    with obs.span("nothing") as sp:
        sp.set_attr("k", 1)  # vanishes
    assert obs.tracer().span_count() == 0
    doc = obs.metrics_doc()
    export.validate_metrics_doc(doc)
    assert doc["enabled"] is False and doc["metrics"] == {}


def test_counter_thread_safety():
    r = reg.MetricsRegistry()
    c = r.counter("c")

    def worker():
        for _ in range(1000):
            c.inc()

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.get() == 8000.0


# ---------------------------------------------------------------------------
# device-side accumulate -> host drain
# ---------------------------------------------------------------------------

def test_jit_accum_drains_into_host_histogram():
    # binary-exact bounds: the device path buckets in float32, so a bound
    # like 0.001 would round differently than the host's float64 compare
    bounds = (0.25, 1.0, 4.0)
    n_slots = len(bounds) + 1

    @jax.jit
    def step(acc, values):
        return reg.hist_bucket_add(acc, values, bounds)

    acc = reg.accum_init(n_slots)
    values = jnp.asarray([0.125, 0.25, 2.0, 100.0, 0.5])
    acc = step(acc, values)
    acc = step(acc, values)

    h = reg.Histogram("h", buckets=bounds)
    h.merge_counts(np.asarray(acc), total=2 * float(values.sum()))
    # boundary 0.25 lands in its own (le) bucket on both paths
    ref = reg.Histogram("ref", buckets=bounds)
    for _ in range(2):
        for v in values.tolist():
            ref.observe(v)
    assert h.snapshot()["counts"] == ref.snapshot()["counts"]
    assert h.sum == pytest.approx(ref.sum, rel=1e-6)


def test_jit_accum_add_counts_indices():
    @jax.jit
    def step(acc, idx):
        return reg.accum_add(acc, idx)

    acc = reg.accum_init(8)
    acc = step(acc, jnp.asarray([[0, 3], [3, 7]]))
    np.testing.assert_array_equal(
        np.asarray(acc), [1, 0, 0, 2, 0, 0, 0, 1]
    )
    acc = reg.accum_add(acc, jnp.asarray([1]), w=jnp.asarray([2.5]))
    assert float(acc[1]) == 2.5


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_span_nesting_parent_links_and_counter_deltas():
    r = obs.configure(enabled=True)
    with obs.span("outer", tag="a") as so:
        obs.counter("work.items").inc(3)
        with obs.span("inner") as si:
            obs.counter("work.items").inc(2)
    spans = {s.name: s for s in obs.tracer().finished}
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert spans["outer"].parent_id is None
    assert spans["inner"].metrics == {"work.items": 2.0}
    assert spans["outer"].metrics == {"work.items": 5.0}
    assert spans["outer"].attrs == {"tag": "a"}
    assert spans["outer"].dur_s >= spans["inner"].dur_s >= 0
    assert so is spans["outer"] and si is spans["inner"]
    assert r.counter("work.items").get() == 5.0


def test_span_events_validate_and_roundtrip(tmp_path):
    obs.configure(metrics_dir=str(tmp_path))
    with obs.span("serve.run", mode="continuous"):
        with obs.span("serve.decode_tick", tick=0):
            obs.counter("serve.tokens").inc(4)
    obs.emit_event("memctl.spill", tick=0, placement="dense->tiered")
    obs.flush()

    events = export.read_jsonl(str(tmp_path / obs.JSONL_NAME))
    kinds = {e["kind"] for e in events}
    assert kinds == {"span", "event", "metrics"}
    by_name = {e["name"]: e for e in events if e["kind"] == "span"}
    assert by_name["serve.decode_tick"]["parent"] == by_name["serve.run"]["id"]
    assert by_name["serve.decode_tick"]["metrics"]["serve.tokens"] == 4.0
    snap = [e for e in events if e["kind"] == "metrics"][-1]["metrics"]
    assert snap["serve.tokens"]["value"] == 4.0

    prom = (tmp_path / obs.PROM_NAME).read_text()
    export.validate_prometheus_text(prom)
    assert "repro_serve_tokens_total 4.0" in prom


# ---------------------------------------------------------------------------
# exporter schemas
# ---------------------------------------------------------------------------

def test_validate_event_rejects_malformed_docs():
    for bad in (
        "not a dict",
        {"kind": "nope"},
        {"kind": "span", "name": "bad name!", "id": 1, "t0_s": 0,
         "dur_s": 0},
        {"kind": "span", "name": "s", "id": "one", "t0_s": 0, "dur_s": 0},
        {"kind": "span", "name": "s", "id": 1, "t0_s": 0, "dur_s": -1},
        {"kind": "span", "name": "s", "id": 1, "t0_s": 0, "dur_s": 0,
         "metrics": {"m": float("nan")}},
        {"kind": "event", "name": "e"},                       # no t_s
        {"kind": "metrics", "t_s": 0, "metrics": {"m": {"kind": "alien"}}},
        {"kind": "metrics", "t_s": 0,
         "metrics": {"h": {"kind": "histogram", "buckets": [1.0],
                           "counts": [1], "sum": 0.0}}},      # len mismatch
    ):
        with pytest.raises(ValueError):
            export.validate_event(bad)


def test_validate_metrics_doc_accepts_live_and_rejects_corrupt():
    obs.configure(enabled=True)
    obs.counter("a.b").inc()
    obs.histogram("a.lat").observe(0.01)
    doc = obs.metrics_doc()
    export.validate_metrics_doc(doc)
    assert doc["schema"] == export.METRICS_SCHEMA
    for corrupt in (
        {**doc, "schema": "v0"},
        {**doc, "enabled": "yes"},
        {**doc, "spans": -1},
        {**doc, "metrics": {"x": {"kind": "counter", "value": None}}},
        [],
    ):
        with pytest.raises(ValueError):
            export.validate_metrics_doc(corrupt)


def test_jsonl_exporter_appends_and_validates(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    ex = export.JsonlExporter(path)
    ex.write_event("e.one", k=1)
    with pytest.raises(ValueError):
        ex.write({"kind": "span", "name": "s"})  # missing fields
    ex.close()
    ex2 = export.JsonlExporter(path)  # append mode: old events survive
    ex2.write_event("e.two")
    ex2.close()
    assert [e["name"] for e in export.read_jsonl(path)] == ["e.one", "e.two"]
    # a corrupted line fails re-validation with its line number
    with open(path, "a", encoding="utf-8") as f:
        f.write(json.dumps({"kind": "event", "name": "bad"}) + "\n")
    with pytest.raises(ValueError, match="ev.jsonl:3"):
        export.read_jsonl(path)


def test_prometheus_text_families():
    r = reg.MetricsRegistry()
    r.counter("serve.tokens", help="decoded tokens").inc(7)
    r.gauge("memctl.num_locations").set(65536)
    h = r.histogram("serve.decode_step_s", buckets=(0.01, 0.1))
    h.observe(0.005)
    h.observe(0.5)
    text = export.prometheus_text(r)
    export.validate_prometheus_text(text)
    assert "# HELP repro_serve_tokens decoded tokens" in text
    assert "repro_serve_tokens_total 7.0" in text
    assert "repro_memctl_num_locations 65536.0" in text
    # cumulative le buckets end at +Inf == count
    assert 'repro_serve_decode_step_s_bucket{le="0.01"} 1' in text
    assert 'repro_serve_decode_step_s_bucket{le="+Inf"} 2' in text
    assert "repro_serve_decode_step_s_count 2" in text


# ---------------------------------------------------------------------------
# zero-overhead guarantees: bit-exact serving, identical train-step HLO
# ---------------------------------------------------------------------------

def _serve_once(params, state, cfg):
    trace = synthetic_trace(
        np.random.default_rng(3), 4, vocab_size=cfg.vocab_size,
        max_prompt=6, max_gen=5, mixed=True,
    )
    engine = ServeEngine(params, state, cfg,
                         EngineConfig(slots=2, max_len=11))
    report = engine.run(trace)
    return {r.id: list(map(int, r.tokens)) for r in report.requests}


def test_metrics_on_serving_is_bit_exact(tmp_path):
    cfg = configs.get_smoke_config("lram-tiered")
    params, state = transformer.init(jax.random.PRNGKey(0), cfg)
    tokens_off = _serve_once(params, state, cfg)
    obs.configure(metrics_dir=str(tmp_path))
    tokens_on = _serve_once(params, state, cfg)
    doc = obs.metrics_doc()
    assert tokens_on == tokens_off
    # ...and the instrumented layers actually reported
    assert doc["metrics"]["serve.tokens"]["value"] > 0
    assert doc["metrics"]["memstore.fills"]["value"] > 0
    assert doc["spans"] > 0
    events = export.read_jsonl(str(tmp_path / obs.JSONL_NAME))
    assert {"serve.run", "serve.decode_tick", "serve.prefill"} <= {
        e["name"] for e in events if e["kind"] == "span"
    }


def test_train_step_hlo_identical_with_obs_armed():
    """The registry/tracer never enter traced code: the non-telemetry train
    step lowers to byte-identical HLO whether obs is armed or not."""
    from repro import data, optim
    from repro.launch.train import build_train_step

    cfg = configs.get_smoke_config("lram-bert-small")
    dcfg = data.DataConfig(vocab_size=cfg.vocab_size, seq_len=8,
                           global_batch=2, kind="facts", objective="mlm")
    opt_cfg = optim.OptimConfig(lr=1e-3)
    params, state = transformer.init(jax.random.PRNGKey(0), cfg)
    opt_state = optim.adam_init(params)
    batch = jax.tree.map(jnp.asarray, data.get_batch(dcfg, step=0))
    args = (params, opt_state, state, jnp.zeros(()), batch)

    hlo_off = build_train_step(cfg, opt_cfg).lower(*args).as_text()
    obs.configure(enabled=True)
    obs.counter("noise").inc()
    hlo_on = build_train_step(cfg, opt_cfg).lower(*args).as_text()
    assert hlo_on == hlo_off


# ---------------------------------------------------------------------------
# satellite: tiered-store stat counters under the prefetch thread pool
# ---------------------------------------------------------------------------

def test_store_stats_consistent_under_concurrent_prefetch():
    """Regression: `prefetch_last` runs on a ThreadPoolExecutor in the
    sharded serve path while the io_callback gather mutates the same
    stats/LRU dicts.  Hammer both concurrently and check the counters
    add up and the cache invariants hold."""
    rng = np.random.default_rng(0)
    rows, shard_rows, slots = 4096, 256, 4
    dense = rng.normal(size=(rows, 8)).astype(np.float32)
    store = TieredValueStore.from_dense(
        dense, TieredSpec(shard_rows=shard_rows, cache_slots=slots)
    )
    idx_sets = [
        rng.integers(0, rows, size=64).astype(np.int32) for _ in range(24)
    ]
    store.gather_rows_host(idx_sets[0])  # seed last_access

    errors = []

    def hammer_prefetch():
        try:
            for _ in range(200):
                store.prefetch_last()
        except Exception as e:  # pragma: no cover - the regression
            errors.append(e)

    def hammer_gather():
        try:
            for idx in idx_sets:
                got = store.gather_rows_host(idx)
                np.testing.assert_allclose(got, dense[idx], rtol=1e-6)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    with concurrent.futures.ThreadPoolExecutor(max_workers=4) as pool:
        futs = [pool.submit(hammer_prefetch) for _ in range(2)]
        futs += [pool.submit(hammer_gather) for _ in range(2)]
        for f in futs:
            f.result()
    assert not errors
    s = store.stats
    # every counted element is a hit, miss, or uncached — no lost updates
    # (prefetch_last never counts; each gather counts all 64 elements)
    assert s["hits"] + s["misses"] + s["uncached"] == 64 * s["lookups"]
    assert s["lookups"] == 1 + 2 * len(idx_sets)
    assert len(store.resident_shards()) <= slots
    # the cache still serves correct rows after the stampede
    probe = rng.integers(0, rows, size=128).astype(np.int32)
    np.testing.assert_allclose(store.gather_rows_host(probe), dense[probe],
                               rtol=1e-6)
