"""Dry-run machinery on a small mesh (subprocess; full pipeline but smoke
configs): lower + compile + cost/memory/collective extraction must work for
every mode (train / prefill / decode) and both mesh layouts."""

import pytest

import textwrap

from conftest import run_in_subprocess


@pytest.mark.slow
def test_lower_compile_and_analyze_all_modes():
    run_in_subprocess(textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs, optim
        from repro.analysis import hlo as hlo_lib
        from repro.configs import shapes as shapes_lib
        from repro.distributed import sharding
        from repro.models import transformer

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = configs.get_smoke_config("yi-9b")
        params, state = jax.eval_shape(
            lambda: transformer.init(jax.random.PRNGKey(0), cfg))
        pspecs = sharding.param_pspecs(params, mesh)
        p_in = jax.tree.map(
            lambda sd, sp: jax.ShapeDtypeStruct(
                sd.shape, sd.dtype, sharding=NamedSharding(mesh, sp)),
            params, pspecs)
        s_in = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(
                sd.shape, sd.dtype, sharding=NamedSharding(mesh, P())),
            state)

        # ---- train ----
        opt_cfg = optim.OptimConfig()
        def train_step(p, o, s, batch):
            (l, (ns, m)), g = jax.value_and_grad(
                transformer.loss_fn, has_aux=True)(p, s, batch, cfg)
            np_, no, st = optim.adam_update(g, o, p, opt_cfg)
            return np_, no, ns, l
        opt_sh = jax.eval_shape(optim.adam_init, params)
        o_in = {"mu": p_in and jax.tree.map(
                    lambda sd, sp: jax.ShapeDtypeStruct(
                        sd.shape, jnp.float32,
                        sharding=NamedSharding(mesh, sp)),
                    params, pspecs),
                "nu": jax.tree.map(
                    lambda sd, sp: jax.ShapeDtypeStruct(
                        sd.shape, jnp.float32,
                        sharding=NamedSharding(mesh, sp)),
                    params, pspecs),
                "step": jax.ShapeDtypeStruct((), jnp.int32,
                        sharding=NamedSharding(mesh, P()))}
        batch = {k: jax.ShapeDtypeStruct((8, 32), jnp.int32,
                 sharding=NamedSharding(mesh, P("data")))
                 for k in ("tokens", "labels")}
        def costd(c):  # newer jaxlib returns [dict]
            cost = c.cost_analysis()
            return cost[0] if isinstance(cost, list) else cost
        c = jax.jit(train_step).lower(p_in, o_in, s_in, batch).compile()
        cost = costd(c)
        assert cost.get("flops", 0) > 0
        coll = hlo_lib.parse_collectives(c.as_text())
        assert coll.counts, "expected collectives in the sharded step"
        assert coll.total_wire_bytes > 0
        print("train OK", cost.get("flops"), coll.counts)

        # ---- decode ----
        cache_sh = transformer.cache_specs(cfg, 8, 64)
        cspec = sharding.cache_pspecs(cache_sh, cfg, mesh)
        c_in = jax.tree.map(
            lambda sd, sp: jax.ShapeDtypeStruct(
                sd.shape, sd.dtype, sharding=NamedSharding(mesh, sp)),
            cache_sh, cspec)
        tok = jax.ShapeDtypeStruct((8, 1), jnp.int32,
                sharding=NamedSharding(mesh, P("data")))
        pos = jax.ShapeDtypeStruct((), jnp.int32,
                sharding=NamedSharding(mesh, P()))
        def serve_step(p, s, t, i, cc):
            return transformer.decode_step(p, s, t, i, cc, cfg)
        c2 = jax.jit(serve_step).lower(p_in, s_in, tok, pos, c_in).compile()
        assert costd(c2).get("flops", 0) > 0
        print("decode OK")

        # ---- multi-pod-style 3-axis mesh ----
        mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        pspecs3 = sharding.param_pspecs(params, mesh3)
        p3 = jax.tree.map(
            lambda sd, sp: jax.ShapeDtypeStruct(
                sd.shape, sd.dtype, sharding=NamedSharding(mesh3, sp)),
            params, pspecs3)
        s3 = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(
                sd.shape, sd.dtype, sharding=NamedSharding(mesh3, P())),
            state)
        b3 = {k: jax.ShapeDtypeStruct((8, 32), jnp.int32,
              sharding=NamedSharding(mesh3, P(("pod", "data"))))
              for k in ("tokens", "labels")}
        def fwd(p, s, b):
            return transformer.loss_fn(p, s, b, cfg)[0]
        c3 = jax.jit(fwd).lower(p3, s3, b3).compile()
        assert costd(c3).get("flops", 0) > 0
        print("multi-pod-mesh OK")
    """), devices=8, timeout=900)


def test_hlo_collective_parser_units():
    from repro.analysis import hlo as hlo_lib

    text = """
  %ag = bf16[8,128]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[256]{0} all-reduce(%x), replica_groups=[32,16]<=[512], to_apply=%sum
  %rs = f32[64]{0} reduce-scatter(%y), replica_groups={{0,1}}, dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %ars = (f32[128]{0}, f32[128]{0}) all-reduce-start(%w), replica_groups={{0,1,2,3}}
"""
    st = hlo_lib.parse_collectives(text)
    assert st.counts == {"all-gather": 1, "all-reduce": 2,
                         "reduce-scatter": 1, "collective-permute": 1}
    # all-gather: (4-1)/4 * 8*128*2 bytes
    assert abs(st.wire_bytes["all-gather"] - 0.75 * 2048) < 1e-6
    # all-reduce: 2*(16-1)/16 * 1024 + async one: 2*(4-1)/4*512
    assert abs(st.wire_bytes["all-reduce"]
               - (2 * 15 / 16 * 1024 + 2 * 0.75 * 512)) < 1e-6
    # reduce-scatter: (2-1) * 256
    assert abs(st.wire_bytes["reduce-scatter"] - 256) < 1e-6
    assert abs(st.wire_bytes["collective-permute"] - 32) < 1e-6
