import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_in_subprocess(code: str, *, devices: int = 1, timeout: int = 600):
    """Run a snippet in a fresh process with N fake JAX devices.

    Multi-device behaviour (shard_map, pjit over meshes, dry-runs) cannot be
    tested in-process: XLA locks the device count at first use, and the main
    test process must keep seeing exactly 1 device.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + env.get("XLA_FLAGS", "").replace(
            "--xla_force_host_platform_device_count", "--ignored"
        )
    )
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
