"""Benchmark-regression gate: compare a fresh BENCH_*.json to the baseline.

    python tools/check_bench.py BENCH_ci.json \
        [--baseline benchmarks/baseline.json] [--threshold 1.3]

Both files are benchmark summary documents (`benchmarks.run --json`
schema; validated via `benchmarks.run.validate_summary`).  The baseline's
rows define the *tracked hot paths*: for every tracked name the current
run must (a) report the row at all and (b) not exceed
``threshold x baseline_us`` (default 1.3x).  Rows with ``us_per_call == 0``
are derived/analytic rows and are tracked for presence only.  Extra rows
in the current run (new benchmarks that have no baseline yet) are listed
but never fail the gate — they start being enforced once
`benchmarks/baseline.json` is refreshed to include them.

``--calibrate NAME`` absorbs machine-speed skew between the baseline
recorder and the gating runner: the threshold is relaxed by
``max(1, cur[NAME] / base[NAME])`` — if the reference row shows the
runner is uniformly 2x slower, tracked rows only fail when they regress
>1.3x *beyond* that.  A faster runner never tightens the gate.  The CI
bench job calibrates on ``tiering_dense_reference`` (a pure device
gather, no scheduling/caching behaviour of its own).

Both documents may carry a ``metrics`` key — the final `repro.obs`
registry snapshot (``repro.obs.v1``).  Schema-invalid docs are rejected at
load time; once the baseline tracks a ``metrics`` doc, a current run
without one fails the gate.

Exit status: 0 = clean, 1 = regression / missing row / missing or invalid
metrics doc / bad input.
CI wires this into the ``bench`` job (see .github/workflows/ci.yml); to
refresh the baseline after an intentional perf change, re-run
``python -m benchmarks.run <tables> --smoke --out benchmarks/baseline.json``
on the reference machine and commit the result.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)
sys.path.insert(0, os.path.join(REPO, "src"))  # repro.obs for metrics docs

from benchmarks.run import validate_summary  # noqa: E402


def load_summary(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    validate_summary(doc)
    return doc


def compare(baseline: dict, current: dict, threshold: float,
            calibrate: str | None = None):
    """Returns (report_lines, failures). Pure — unit-testable."""
    base_rows = {name: us for name, us, _ in baseline["rows"]
                 if not name.endswith(".ERROR")}
    cur_rows = {name: us for name, us, _ in current["rows"]
                if not name.endswith(".ERROR")}
    failures: list[str] = []
    if calibrate is not None:
        if base_rows.get(calibrate, 0) <= 0 or cur_rows.get(calibrate, 0) <= 0:
            failures.append(
                f"calibration row {calibrate!r} missing or zero in "
                f"baseline/current"
            )
        else:
            scale = max(1.0, cur_rows[calibrate] / base_rows[calibrate])
            threshold *= scale
    width = max((len(n) for n in base_rows), default=4)
    lines = []
    if calibrate is not None and not failures:
        lines.append(f"calibrated on {calibrate}: effective threshold "
                     f"{threshold:.2f}x")
    lines.append(f"{'name':<{width}}  {'base_us':>12}  {'cur_us':>12}  "
                 f"{'ratio':>7}  status")
    for name in sorted(base_rows):
        base_us = base_rows[name]
        if name not in cur_rows:
            failures.append(f"tracked row missing from current run: {name}")
            lines.append(f"{name:<{width}}  {base_us:>12.3f}  "
                         f"{'-':>12}  {'-':>7}  MISSING")
            continue
        cur_us = cur_rows[name]
        if base_us <= 0:
            lines.append(f"{name:<{width}}  {base_us:>12.3f}  "
                         f"{cur_us:>12.3f}  {'-':>7}  PRESENT")
            continue
        ratio = cur_us / base_us
        status = "OK" if ratio <= threshold else "REGRESSED"
        if status == "REGRESSED":
            failures.append(
                f"{name}: {cur_us:.3f}us vs baseline {base_us:.3f}us "
                f"({ratio:.2f}x > {threshold:g}x)"
            )
        lines.append(f"{name:<{width}}  {base_us:>12.3f}  "
                     f"{cur_us:>12.3f}  {ratio:>6.2f}x  {status}")
    for name in sorted(set(cur_rows) - set(base_rows)):
        lines.append(f"{name:<{width}}  {'-':>12}  "
                     f"{cur_rows[name]:>12.3f}  {'-':>7}  NEW (untracked)")
    error_rows = [name for name, _, _ in current["rows"]
                  if name.endswith(".ERROR")]
    for name in error_rows:
        failures.append(f"benchmark module errored: {name}")
    # observability gate: once the baseline carries a `metrics` doc
    # (repro.obs.v1 registry snapshot), every gated run must too.
    # Schema validity is enforced at load time (`validate_summary`
    # delegates to repro.obs.export.validate_metrics_doc); here we catch
    # the doc going missing — an instrumented layer silently dropping
    # its telemetry would otherwise pass the latency gate unnoticed.
    if "metrics" in baseline and "metrics" not in current:
        failures.append(
            "summary 'metrics' doc missing from current run (baseline "
            "tracks one; run benchmarks.run with the repro.obs layer "
            "present)"
        )
    return lines, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh summary (BENCH_*.json)")
    ap.add_argument("--baseline",
                    default=os.path.join(REPO, "benchmarks", "baseline.json"))
    ap.add_argument("--threshold", type=float, default=1.3,
                    help="max allowed cur/base latency ratio per tracked "
                         "hot path (default 1.3)")
    ap.add_argument("--calibrate", default=None, metavar="NAME",
                    help="tracked row used to absorb machine-speed skew: "
                         "threshold scales by max(1, cur/base) of this row")
    args = ap.parse_args(argv)
    try:
        baseline = load_summary(args.baseline)
        current = load_summary(args.current)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"check_bench: bad input: {e}", file=sys.stderr)
        return 1
    lines, failures = compare(baseline, current, args.threshold,
                              calibrate=args.calibrate)
    print("\n".join(lines))
    for f in failures:
        print(f"REGRESSION: {f}", file=sys.stderr)
    print(f"check_bench: {'FAIL' if failures else 'OK'} "
          f"({len(failures)} failure(s), threshold {args.threshold:g}x)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
