"""Keep the docs honest: smoke-run documented commands, check links.

    python tools/check_docs.py [--level help|smoke] [--no-commands]

Two checks (CI runs both in the `docs` job; see .github/workflows/ci.yml):

1. **Dead links** — every relative markdown link in README.md and
   docs/**/*.md must resolve to an existing file.

2. **Documented commands run** — every `python ...` line inside a
   ```bash fence of README.md is executed so documented entry points
   cannot rot:

     * `--level help` (default): each command runs with `--help` — proves
       the module imports and exposes the documented CLI.
     * `--level smoke`: launcher commands run for real, rewritten to the
       smallest footprint (`--steps 2`, tiny gen/prompt sizes); benchmark
       commands run `--help`-level (their full sweeps are tier-2);
       `benchmarks.run <tables>` is checked by importing the selected
       table modules.

   `pip ...` and `pytest` lines are skipped (the install/tier-1 CI jobs
   own those).
"""

from __future__ import annotations

import argparse
import os
import re
import shlex
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMOKE_OVERRIDES = {
    "repro.launch.train": ["--smoke", "--steps", "2", "--log-every", "1"],
    "repro.launch.serve": ["--smoke", "--batch", "2", "--prompt-len", "4",
                           "--gen", "3"],
}
# benchmark sweeps are tier-2; at smoke level only prove they import/parse
HELP_ONLY_AT_SMOKE = ("benchmarks.table",)


def iter_markdown_files():
    yield os.path.join(REPO, "README.md")
    docs = os.path.join(REPO, "docs")
    for root, _, files in os.walk(docs):
        for f in sorted(files):
            if f.endswith(".md"):
                yield os.path.join(root, f)


def check_links() -> list[str]:
    """Relative markdown links must point at existing files."""
    errors = []
    link_re = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
    for path in iter_markdown_files():
        text = open(path, encoding="utf-8").read()
        # strip fenced code blocks: `](` inside code is not a link
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in link_re.findall(text):
            if re.match(r"^[a-z]+:", target) or target.startswith("#"):
                continue  # external or intra-page anchor
            rel = target.split("#")[0]
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), rel)
            )
            if not os.path.exists(resolved):
                errors.append(
                    f"{os.path.relpath(path, REPO)}: dead link -> {target}"
                )
    return errors


def documented_commands() -> list[list[str]]:
    """`python ...` lines from README bash fences (continuations joined)."""
    text = open(os.path.join(REPO, "README.md"), encoding="utf-8").read()
    cmds = []
    for block in re.findall(r"```bash\n(.*?)```", text, flags=re.S):
        block = block.replace("\\\n", " ")
        for line in block.splitlines():
            line = line.split("#")[0].strip()
            if line.startswith("python "):
                cmds.append(shlex.split(line))
    return cmds


def plan(cmd: list[str], level: str) -> list[str] | None:
    """Rewrite a documented command for the requested check level;
    None = skip."""
    if cmd[:2] == ["python", "-m"]:
        module, args = cmd[2], cmd[3:]
    else:
        return None  # `python path/to/script.py` is not documented today
    if module in ("pytest", "pip"):
        return None
    if module == "benchmarks.run":
        # prove the documented table selections resolve to real modules
        from importlib import import_module
        sys.path.insert(0, REPO)
        run_mod = import_module("benchmarks.run")
        mods = [m for m in run_mod.MODULES
                if not args or m.split("_")[0] in
                {a.split("_")[0] for a in args}]
        assert mods, f"no benchmark modules match {args}"
        return [sys.executable, "-c",
                ";".join(f"import benchmarks.{m}" for m in mods)]
    if level == "help" or module.startswith(HELP_ONLY_AT_SMOKE):
        return [sys.executable, "-m", module, "--help"]
    extra = SMOKE_OVERRIDES.get(module, [])
    return [sys.executable, "-m", module, *args, *extra]  # argparse: last wins


def check_commands(level: str) -> list[str]:
    errors = []
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    for cmd in documented_commands():
        final = plan(cmd, level)
        if final is None:
            print(f"SKIP  {' '.join(cmd)}")
            continue
        print(f"RUN   {' '.join(cmd)}  ->  {' '.join(final)}", flush=True)
        try:
            proc = subprocess.run(final, cwd=REPO, env=env,
                                  capture_output=True, text=True,
                                  timeout=1200)
        except subprocess.TimeoutExpired:
            errors.append(f"documented command timed out: {' '.join(cmd)}")
            continue
        if proc.returncode != 0:
            errors.append(
                f"documented command failed: {' '.join(cmd)}\n"
                f"  as: {' '.join(final)}\n"
                f"  stderr tail: {proc.stderr.strip()[-2000:]}"
            )
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--level", choices=["help", "smoke"], default="help")
    ap.add_argument("--no-commands", action="store_true",
                    help="dead-link check only")
    args = ap.parse_args(argv)
    errors = check_links()
    if not args.no_commands:
        errors += check_commands(args.level)
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"check_docs: {'FAIL' if errors else 'OK'} "
          f"({len(errors)} error(s))")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
